//! Fig. 2: measured performance vs the sparsity-aware roofline, one
//! panel per structural class.
//!
//! Each panel shows the bandwidth roof `P = β·AI` (the memory-bound
//! region only — SpMM never reaches the ridge), vertical lines at the
//! class model's AI for each `d`, and the measured (AI, GFLOP/s)
//! points for every implementation.

use crate::config::ExperimentConfig;
use crate::error::Result;
use crate::gen::{representative_suite, SparsityClass};
use crate::harness::common::{machine_params_cached, measure_kernel};
use crate::model::{AiParams, MachineParams, Roofline, SparsityModel};
use crate::pattern::classify;
use crate::report::{write_csv, Marker, Series, SvgPlot, Table, VLine, PALETTE};
use crate::spmm::{build_native, Impl};

/// One measured point in roofline space.
#[derive(Debug, Clone)]
pub struct Fig2Point {
    pub matrix: String,
    pub class: SparsityClass,
    pub d: usize,
    pub im: Impl,
    /// Model AI for (matrix, d) under the class model.
    pub ai: f64,
    /// Bandwidth roof at that AI.
    pub roof_gflops: f64,
    pub measured_gflops: f64,
}

impl Fig2Point {
    /// measured / roof — Fig. 2's "closeness to the roofline".
    pub fn efficiency(&self) -> f64 {
        if self.roof_gflops > 0.0 {
            self.measured_gflops / self.roof_gflops
        } else {
            0.0
        }
    }
}

/// The full Fig. 2 dataset.
#[derive(Debug, Clone)]
pub struct Fig2Data {
    pub machine: MachineParams,
    pub points: Vec<Fig2Point>,
    /// Per matrix: the parameterised model used (for annotation).
    pub models: Vec<(String, SparsityModel)>,
}

/// Run the Fig. 2 experiment: measure all impls × d on the four
/// representative matrices and place them against their class
/// rooflines.
pub fn run_fig2(cfg: &ExperimentConfig, machine: Option<MachineParams>) -> Result<Fig2Data> {
    let machine = machine.unwrap_or_else(|| machine_params_cached(cfg.threads));
    let roofline = Roofline::new(machine);
    let mut points = Vec::new();
    let mut models = Vec::new();
    for proxy in representative_suite() {
        let csr = proxy.generate(cfg.scale);
        // classify — rather than trusting provenance — so Fig. 2 also
        // exercises the engine's model-selection path
        let cls = classify(&csr);
        models.push((proxy.name.to_string(), cls.model));
        for &im in &cfg.impls {
            if im == Impl::Xla {
                continue;
            }
            let kernel = build_native(im, &csr, cfg.threads)?;
            for &d in &cfg.d_values {
                let ai = cls.model.ai(AiParams::new(csr.nrows, d, csr.nnz()));
                let m = measure_kernel(kernel.as_ref(), d, cfg.iters, cfg.warmup)?;
                points.push(Fig2Point {
                    matrix: proxy.name.to_string(),
                    class: proxy.class,
                    d,
                    im,
                    ai,
                    roof_gflops: roofline.attainable_gflops(ai),
                    measured_gflops: m.gflops,
                });
            }
        }
    }
    Ok(Fig2Data { machine, points, models })
}

impl Fig2Data {
    /// One SVG per matrix (`fig2_<matrix>.svg`): roof line, AI
    /// verticals, measured points.
    pub fn save_svgs(&self, out_dir: &str) -> Result<Vec<String>> {
        let mut paths = Vec::new();
        let matrices: Vec<String> = self.models.iter().map(|(n, _)| n.clone()).collect();
        for name in matrices {
            let pts: Vec<&Fig2Point> = self.points.iter().filter(|p| p.matrix == name).collect();
            if pts.is_empty() {
                continue;
            }
            let class = pts[0].class;
            let mut plot = SvgPlot::new(
                format!("Fig.2 — {name} ({class}) roofline"),
                "arithmetic intensity (FLOP/byte)",
                "GFLOP/s",
            )
            .log_axes(true, true);
            // bandwidth roof across the AI range
            let (ai_lo, ai_hi) = pts.iter().fold((f64::INFINITY, 0.0f64), |(l, h), p| {
                (l.min(p.ai), h.max(p.ai))
            });
            let lo = ai_lo * 0.5;
            let hi = ai_hi * 2.0;
            plot.add_series(Series {
                label: format!("roof β·AI (β={:.1} GB/s)", self.machine.beta_gbs),
                points: vec![
                    (lo, self.machine.beta_gbs * lo),
                    (hi, self.machine.beta_gbs * hi),
                ],
                color: "#333333".into(),
                marker: Marker::None,
                line: true,
            });
            // vertical model-AI lines per d
            let mut ds: Vec<usize> = pts.iter().map(|p| p.d).collect();
            ds.sort_unstable();
            ds.dedup();
            for &d in &ds {
                if let Some(p) = pts.iter().find(|p| p.d == d) {
                    plot.add_vline(VLine {
                        x: p.ai,
                        label: format!("AI d={d}"),
                        color: "#999999".into(),
                    });
                }
            }
            // measured points per impl
            let mut impls: Vec<Impl> = pts.iter().map(|p| p.im).collect();
            impls.sort_by_key(|im| im.to_string());
            impls.dedup();
            let markers = [Marker::Circle, Marker::Square, Marker::Triangle, Marker::Diamond];
            for (i, im) in impls.iter().enumerate() {
                let series_pts: Vec<(f64, f64)> = pts
                    .iter()
                    .filter(|p| p.im == *im)
                    .map(|p| (p.ai, p.measured_gflops))
                    .collect();
                plot.add_series(Series::scatter(
                    im.to_string(),
                    PALETTE[i % PALETTE.len()],
                    markers[i % markers.len()],
                    series_pts,
                ));
            }
            let path = format!("{out_dir}/fig2_{name}.svg");
            plot.save(&path)?;
            paths.push(path);
        }
        Ok(paths)
    }

    /// CSV of every point.
    pub fn save_csv(&self, path: &str) -> Result<()> {
        let rows: Vec<Vec<String>> = self
            .points
            .iter()
            .map(|p| {
                vec![
                    p.matrix.clone(),
                    p.class.to_string(),
                    p.d.to_string(),
                    p.im.to_string(),
                    format!("{:.6}", p.ai),
                    format!("{:.4}", p.roof_gflops),
                    format!("{:.4}", p.measured_gflops),
                    format!("{:.4}", p.efficiency()),
                ]
            })
            .collect();
        write_csv(
            path,
            &["matrix", "class", "d", "impl", "ai_model", "roof_gflops", "measured_gflops", "efficiency"],
            &rows,
        )
    }

    /// Text table: AI, roof, measured, efficiency per point.
    pub fn render(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Fig.2 — model AI vs measured (β={:.1} GB/s, π={:.0} GFLOP/s)",
                self.machine.beta_gbs, self.machine.pi_gflops
            ),
            &["Matrix", "d", "Impl", "AI model", "Roof GF/s", "Meas GF/s", "Meas/Roof"],
        );
        for p in &self.points {
            t.row(vec![
                p.matrix.clone(),
                p.d.to_string(),
                p.im.to_string(),
                format!("{:.4}", p.ai),
                format!("{:.2}", p.roof_gflops),
                format!("{:.2}", p.measured_gflops),
                format!("{:.2}", p.efficiency()),
            ]);
        }
        t
    }

    /// The paper's §IV-D shape claims, as checkable predicates.
    pub fn shape_checks(&self) -> Vec<(String, bool)> {
        let mut checks = Vec::new();
        let eff = |class: SparsityClass, im: Impl| -> Vec<f64> {
            self.points
                .iter()
                .filter(|p| p.class == class && p.im == im)
                .map(|p| p.efficiency())
                .collect()
        };
        let mean = |xs: &[f64]| {
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<f64>() / xs.len() as f64
            }
        };
        // (1) random: everything well below the roof (lower-bound AI
        //     model + latency effects)
        for im in [Impl::Csr, Impl::Opt, Impl::Csb] {
            let e = mean(&eff(SparsityClass::Random, im));
            checks.push((format!("random/{im}: efficiency {e:.2} < 1"), e < 1.0));
        }
        // (2) diagonal: the model is an upper bound
        for im in [Impl::Csr, Impl::Opt, Impl::Csb] {
            let e = mean(&eff(SparsityClass::Diagonal, im));
            checks.push((format!("diagonal/{im}: efficiency {e:.2} < 1"), e < 1.0));
        }
        // (3) CSB is the closest to the roof on blocked matrices
        let csb = mean(&eff(SparsityClass::Blocked, Impl::Csb));
        let csr = mean(&eff(SparsityClass::Blocked, Impl::Csr));
        checks.push((
            format!("blocked: CSB efficiency ({csb:.2}) > CSR ({csr:.2})"),
            csb > csr,
        ));
        checks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_fig2_runs() {
        let cfg = ExperimentConfig {
            scale: 0.02,
            d_values: vec![1, 16],
            threads: 1,
            iters: 1,
            warmup: 0,
            ..Default::default()
        };
        let machine = MachineParams { beta_gbs: 10.0, pi_gflops: 100.0 };
        let data = run_fig2(&cfg, Some(machine)).unwrap();
        assert_eq!(data.points.len(), 4 * 3 * 2);
        assert!(data.points.iter().all(|p| p.ai > 0.0 && p.roof_gflops > 0.0));
        let dir = std::env::temp_dir().join("spmm_fig2_test");
        let paths = data.save_svgs(dir.to_str().unwrap()).unwrap();
        assert_eq!(paths.len(), 4);
        assert!(!data.shape_checks().is_empty());
        // AI ordering: diagonal model AI must exceed random model AI
        // at the same d (compare across the two matrices)
        let ai_of = |m: &str, d: usize| {
            data.points.iter().find(|p| p.matrix == m && p.d == d).unwrap().ai
        };
        assert!(ai_of("rajat31_p", 16) > ai_of("er_18_1", 16));
    }
}
