//! The experiment harness: one function per paper table/figure, plus
//! the model-validation and ablation studies (DESIGN.md §5 experiment
//! index).
//!
//! Every experiment returns structured data *and* renders itself
//! (text table, CSV, SVG where the paper has a figure), so the CLI,
//! the bench binaries and the examples all share one code path.

mod ablations;
mod common;
pub mod corpus;
mod fig1;
mod fig2;
mod table_v;
pub mod validate;

pub use ablations::{
    ablate_block_size, ablate_reorder, ablate_reuse_factor, ablate_threads, traffic_vs_d,
    z_model_grid,
};
pub use corpus::{
    ingest_dir, run_corpus, synthesize_corpus, CorpusConfig, CorpusMatrix, CorpusReport,
    CorpusRow, GroupRow, CORPUS_DEFAULT_BUDGET,
};
pub use common::{machine_params_cached, measure_kernel, CellMeasurement};
pub use fig1::{run_fig1, Fig1Data};
pub use fig2::{run_fig2, Fig2Data, Fig2Point};
pub use table_v::{paper_table_v, run_table_v, TableVData, TableVRow};
pub use validate::{run_validate_ai, ValidationRow};
