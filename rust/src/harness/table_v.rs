//! Table V: SpMM GFLOP/s for every proxy matrix × implementation ×
//! dense width.

use crate::config::ExperimentConfig;
use crate::error::Result;
use crate::gen::{proxy_suite, SparsityClass};
use crate::harness::common::measure_kernel;
use crate::report::{fmt3, write_csv, Table};
use crate::spmm::{build_native, Impl};

/// One measured cell.
#[derive(Debug, Clone)]
pub struct TableVRow {
    pub name: String,
    pub paper_name: String,
    pub class: SparsityClass,
    pub n: usize,
    pub nnz: usize,
    pub d: usize,
    pub im: Impl,
    pub gflops: f64,
}

/// The full grid.
#[derive(Debug, Clone, Default)]
pub struct TableVData {
    pub rows: Vec<TableVRow>,
}

/// The paper's Table V (GFLOP/s on one EPYC-7763 socket) for shape
/// comparison: `(paper_name, d, impl_paper_name) -> gflops`.
pub fn paper_table_v() -> Vec<(&'static str, usize, &'static str, f64)> {
    // transcribed from the paper (CSR, MKL, CSB per d)
    let data: [(&str, [[f64; 3]; 4]); 12] = [
        ("road_usa", [[9.468, 11.0924, 14.240], [17.528, 17.289, 25.423], [32.768, 32.652, 36.234], [41.316, 38.567, 43.006]]),
        ("hugebubbles-00010", [[5.875, 7.146, 9.696], [14.358, 13.490, 15.853], [21.743, 22.975, 28.322], [21.743, 22.975, 28.322]]),
        ("asia_osm", [[7.301, 10.078, 10.668], [20.455, 21.481, 14.027], [33.975, 34.568, 35.093], [38.345, 38.450, 33.479]]),
        ("333SP", [[5.284, 8.692, 13.057], [12.258, 23.625, 24.875], [28.784, 28.893, 35.227], [29.729, 30.106, 39.596]]),
        ("com-Orkut", [[8.402, 18.340, 26.894], [14.505, 30.560, 38.501], [21.037, 29.053, 34.403], [12.256, 22.460, 32.017]]),
        ("com-LiveJournal", [[11.536, 15.010, 26.984], [35.687, 44.851, 72.008], [66.266, 76.981, 92.091], [41.683, 53.544, 61.322]]),
        ("uk-2002", [[16.701, 24.139, 16.204], [55.851, 78.538, 67.526], [146.583, 167.960, 148.299], [226.757, 205.945, 164.359]]),
        ("ideal_diagonal_22", [[1.988, 1.167, 5.886], [23.546, 10.558, 6.840], [8.5888, 9.039, 14.202], [10.902, 11.023, 17.294]]),
        ("rajat31", [[7.266, 9.565, 9.390], [26.944, 29.348, 22.601], [56.978, 59.644, 39.275], [74.064, 69.266, 53.911]]),
        ("er_22_1", [[1.586, 1.634, 3.998], [4.957, 5.446, 6.226], [7.841, 8.194, 10.216], [8.547, 5.320, 11.509]]),
        ("er_22_10", [[6.194, 7.833, 12.832], [13.921, 15.225, 12.373], [12.284, 12.374, 13.456], [10.0322, 11.185, 17.036]]),
        ("er_22_20", [[8.091, 10.906, 16.283], [14.979, 16.249, 15.453], [13.575, 14.169, 13.483], [11.564, 10.429, 17.001]]),
    ];
    let ds = [1usize, 4, 16, 64];
    let impls = ["CSR", "MKL", "CSB"];
    let mut out = Vec::new();
    for (name, grid) in data {
        for (di, &d) in ds.iter().enumerate() {
            for (ii, &im) in impls.iter().enumerate() {
                out.push((name, d, im, grid[di][ii]));
            }
        }
    }
    out
}

/// Run the Table V sweep with the configured scale/impls/widths.
pub fn run_table_v(cfg: &ExperimentConfig) -> Result<TableVData> {
    let mut data = TableVData::default();
    for proxy in proxy_suite() {
        let csr = proxy.generate(cfg.scale);
        for &im in &cfg.impls {
            if im == Impl::Xla {
                continue; // XLA is measured in bench_xla (fixed shapes)
            }
            let kernel = build_native(im, &csr, cfg.threads)?;
            for &d in &cfg.d_values {
                let m = measure_kernel(kernel.as_ref(), d, cfg.iters, cfg.warmup)?;
                data.rows.push(TableVRow {
                    name: proxy.name.to_string(),
                    paper_name: proxy.paper_name.to_string(),
                    class: proxy.class,
                    n: csr.nrows,
                    nnz: csr.nnz(),
                    d,
                    im,
                    gflops: m.gflops,
                });
            }
        }
    }
    Ok(data)
}

impl TableVData {
    /// Lookup one cell.
    pub fn get(&self, name: &str, d: usize, im: Impl) -> Option<f64> {
        self.rows
            .iter()
            .find(|r| r.name == name && r.d == d && r.im == im)
            .map(|r| r.gflops)
    }

    /// Render in the paper's layout: one row per matrix, columns
    /// grouped by d then impl.
    pub fn render(&self, cfg: &ExperimentConfig) -> Table {
        let impls: Vec<Impl> = cfg.impls.iter().copied().filter(|&i| i != Impl::Xla).collect();
        let mut headers: Vec<String> = vec!["Pattern".into(), "Matrix".into()];
        for &d in &cfg.d_values {
            for im in &impls {
                headers.push(format!("d={d} {im}"));
            }
        }
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(
            "Table V — SpMM performance (GFLOP/s) across formats (proxy dataset)",
            &hdr_refs,
        );
        let mut names: Vec<(SparsityClass, String)> = Vec::new();
        for r in &self.rows {
            if !names.iter().any(|(_, n)| n == &r.name) {
                names.push((r.class, r.name.clone()));
            }
        }
        for (class, name) in names {
            let mut cells = vec![class.to_string(), name.clone()];
            for &d in &cfg.d_values {
                for &im in &impls {
                    cells.push(self.get(&name, d, im).map(fmt3).unwrap_or_else(|| "-".into()));
                }
            }
            t.row(cells);
        }
        t
    }

    /// Write the raw grid as CSV.
    pub fn save_csv(&self, path: &str) -> Result<()> {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.name.clone(),
                    r.paper_name.clone(),
                    r.class.to_string(),
                    r.n.to_string(),
                    r.nnz.to_string(),
                    r.d.to_string(),
                    r.im.to_string(),
                    format!("{:.4}", r.gflops),
                ]
            })
            .collect();
        write_csv(path, &["name", "paper_name", "class", "n", "nnz", "d", "impl", "gflops"], &rows)
    }

    /// Shape checks against the paper's claims (§IV-C): returns
    /// human-readable pass/fail lines. Used by EXPERIMENTS.md and the
    /// integration tests.
    pub fn shape_checks(&self, cfg: &ExperimentConfig) -> Vec<(String, bool)> {
        let mut checks = Vec::new();
        let class_mean = |class: SparsityClass, d: usize| -> f64 {
            let xs: Vec<f64> = self
                .rows
                .iter()
                .filter(|r| r.class == class && r.d == d)
                .map(|r| r.gflops)
                .collect();
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<f64>() / xs.len() as f64
            }
        };
        // 1. random lowest, scale-free highest (paper §IV-C) at d=16
        let d_mid = *cfg.d_values.get(2).unwrap_or(&16);
        let rand = class_mean(SparsityClass::Random, d_mid);
        let sf = class_mean(SparsityClass::ScaleFree, d_mid);
        let blocked = class_mean(SparsityClass::Blocked, d_mid);
        checks.push((
            format!("scale-free ({sf:.2}) > random ({rand:.2}) at d={d_mid}"),
            sf > rand,
        ));
        checks.push((
            format!("blocked ({blocked:.2}) > random ({rand:.2}) at d={d_mid}"),
            blocked > rand,
        ));
        // 2. performance improves from d=1 to d=16 for every class
        if cfg.d_values.contains(&1) && cfg.d_values.contains(&16) {
            for class in [
                SparsityClass::Blocked,
                SparsityClass::ScaleFree,
                SparsityClass::Diagonal,
                SparsityClass::Random,
            ] {
                let lo = class_mean(class, 1);
                let hi = class_mean(class, 16);
                checks.push((format!("{class}: d=16 ({hi:.2}) > d=1 ({lo:.2})"), hi > lo));
            }
        }
        checks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table_has_all_cells() {
        let p = paper_table_v();
        assert_eq!(p.len(), 12 * 4 * 3);
        // spot check against the published table
        assert!(p.contains(&("road_usa", 1, "CSR", 9.468)));
        assert!(p.contains(&("er_22_20", 64, "CSB", 17.001)));
    }

    #[test]
    fn tiny_sweep_produces_grid() {
        let cfg = ExperimentConfig {
            scale: 0.02,
            d_values: vec![1, 4],
            threads: 1,
            iters: 1,
            warmup: 0,
            ..Default::default()
        };
        let data = run_table_v(&cfg).unwrap();
        assert_eq!(data.rows.len(), 12 * 3 * 2);
        assert!(data.rows.iter().all(|r| r.gflops > 0.0));
        let t = data.render(&cfg);
        assert_eq!(t.rows.len(), 12);
        let checks = data.shape_checks(&cfg);
        assert!(!checks.is_empty());
    }
}
