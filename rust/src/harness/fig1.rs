//! Fig. 1: GFLOP/s vs dense width `d` for one representative matrix
//! per sparsity class.

use crate::config::ExperimentConfig;
use crate::error::Result;
use crate::gen::{representative_suite, SparsityClass};
use crate::harness::common::measure_kernel;
use crate::report::{write_csv, Series, SvgPlot, Table, PALETTE};
use crate::spmm::{build_native, Impl};

/// Measured curves for one matrix: per impl, (d, gflops) points.
#[derive(Debug, Clone)]
pub struct Fig1Data {
    pub matrices: Vec<(String, SparsityClass, Vec<(Impl, Vec<(usize, f64)>)>)>,
    pub d_values: Vec<usize>,
}

/// Run the Fig. 1 sweep over the four representative proxies.
pub fn run_fig1(cfg: &ExperimentConfig) -> Result<Fig1Data> {
    let mut matrices = Vec::new();
    for proxy in representative_suite() {
        let csr = proxy.generate(cfg.scale);
        let mut series = Vec::new();
        for &im in &cfg.impls {
            if im == Impl::Xla {
                continue;
            }
            let kernel = build_native(im, &csr, cfg.threads)?;
            let mut pts: Vec<(usize, f64)> = Vec::with_capacity(cfg.d_values.len());
            for &d in &cfg.d_values {
                let m = measure_kernel(kernel.as_ref(), d, cfg.iters, cfg.warmup)?;
                pts.push((d, m.gflops));
            }
            series.push((im, pts));
        }
        matrices.push((proxy.name.to_string(), proxy.class, series));
    }
    Ok(Fig1Data { matrices, d_values: cfg.d_values.clone() })
}

impl Fig1Data {
    /// One SVG per matrix, named `fig1_<matrix>.svg`, in `out_dir`.
    pub fn save_svgs(&self, out_dir: &str) -> Result<Vec<String>> {
        let mut paths = Vec::new();
        for (name, class, series) in &self.matrices {
            let mut plot = SvgPlot::new(
                format!("Fig.1 — {name} ({class})"),
                "columns d (log2)",
                "GFLOP/s",
            )
            .log_axes(true, false);
            for (i, (im, pts)) in series.iter().enumerate() {
                let fp: Vec<(f64, f64)> = pts.iter().map(|&(d, g)| (d as f64, g)).collect();
                plot.add_series(Series::line(im.to_string(), PALETTE[i % PALETTE.len()], fp));
            }
            let path = format!("{out_dir}/fig1_{name}.svg");
            plot.save(&path)?;
            paths.push(path);
        }
        Ok(paths)
    }

    /// CSV of every point.
    pub fn save_csv(&self, path: &str) -> Result<()> {
        let mut rows = Vec::new();
        for (name, class, series) in &self.matrices {
            for (im, pts) in series {
                for &(d, g) in pts {
                    rows.push(vec![
                        name.clone(),
                        class.to_string(),
                        im.to_string(),
                        d.to_string(),
                        format!("{g:.4}"),
                    ]);
                }
            }
        }
        write_csv(path, &["matrix", "class", "impl", "d", "gflops"], &rows)
    }

    /// Text summary table.
    pub fn render(&self) -> Table {
        let mut headers: Vec<String> = vec!["Matrix".into(), "Impl".into()];
        headers.extend(self.d_values.iter().map(|d| format!("d={d}")));
        let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new("Fig.1 — GFLOP/s vs d (representative matrices)", &hdr);
        for (name, _class, series) in &self.matrices {
            for (im, pts) in series {
                let mut row = vec![name.clone(), im.to_string()];
                for &d in &self.d_values {
                    let g = pts.iter().find(|p| p.0 == d).map(|p| p.1).unwrap_or(0.0);
                    row.push(format!("{g:.2}"));
                }
                t.row(row);
            }
        }
        t
    }

    /// ASCII scatter markers kept out; the SVG is the figure. This
    /// helper exposes the per-class best-d for shape checks.
    pub fn best_d(&self, matrix: &str, im: Impl) -> Option<usize> {
        self.matrices
            .iter()
            .find(|(n, _, _)| n == matrix)?
            .2
            .iter()
            .find(|(i, _)| *i == im)?
            .1
            .iter()
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .map(|&(d, _)| d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_fig1_runs() {
        let cfg = ExperimentConfig {
            scale: 0.02,
            d_values: vec![1, 8],
            threads: 1,
            iters: 1,
            warmup: 0,
            ..Default::default()
        };
        let data = run_fig1(&cfg).unwrap();
        assert_eq!(data.matrices.len(), 4);
        let t = data.render();
        assert_eq!(t.rows.len(), 4 * 3);
        let dir = std::env::temp_dir().join("spmm_fig1_test");
        let paths = data.save_svgs(dir.to_str().unwrap()).unwrap();
        assert_eq!(paths.len(), 4);
        assert!(std::path::Path::new(&paths[0]).exists());
        assert!(data.best_d("er_18_1", Impl::Csr).is_some());
    }
}
