//! Ablation studies (A1–A3 in DESIGN.md §5):
//!
//! * **A1 block size** — CSB performance and the occupancy statistics
//!   (`D`, modeled vs measured `z`) as the block dimension `t` sweeps.
//!   Probes the `z = t(1 − e^{−D/t})` model and the paper's implicit
//!   choice of block size.
//! * **A2 reuse factor** — the paper scales CSB's B-traffic by a ¼
//!   heuristic "based on observed experimental results". The cache
//!   simulator lets us *measure* that factor: simulated B-attributable
//!   DRAM bytes / the unscaled `8dNz` model term.
//! * **A3 threads** — scaling over worker threads (bounded by the
//!   single physical core of this testbed; documented in
//!   EXPERIMENTS.md).

use crate::cachesim::{trace_csb_spmm, trace_csr_spmm, Hierarchy, HierarchyConfig};
use crate::config::ExperimentConfig;
use crate::error::Result;
use crate::gen::suite::find;
use crate::harness::common::measure_kernel;
use crate::model::{expected_z, BlockStats};
use crate::report::Table;
use crate::sparse::Csb;
use crate::spmm::{CsbSpmm, CsrSpmm, OptSpmm, Spmm};

/// A1: CSB block-size sweep on one matrix. Returns
/// `(t, D, z_model, z_measured, gflops)` rows.
pub fn ablate_block_size(
    cfg: &ExperimentConfig,
    matrix: &str,
    d: usize,
    block_dims: &[usize],
) -> Result<(Table, Vec<(usize, f64, f64, f64, f64)>)> {
    let proxy = find(matrix)
        .ok_or_else(|| crate::Error::Usage(format!("unknown proxy matrix '{matrix}'")))?;
    let csr = proxy.generate(cfg.scale);
    let mut rows = Vec::new();
    let mut t = Table::new(
        format!("A1 — CSB block-size sweep on {matrix} (d={d})"),
        &["t", "N blocks", "D=nnz/N", "z model", "z measured", "GFLOP/s"],
    );
    for &bd in block_dims {
        let kernel = CsbSpmm::from_csr_with_block(&csr, bd, cfg.threads);
        let st = BlockStats::of(kernel.matrix());
        let m = measure_kernel(&kernel, d, cfg.iters, cfg.warmup)?;
        t.row(vec![
            bd.to_string(),
            st.n_blocks.to_string(),
            format!("{:.2}", st.avg_density),
            format!("{:.2}", st.z_model),
            format!("{:.2}", st.z_measured),
            format!("{:.3}", m.gflops),
        ]);
        rows.push((bd, st.avg_density, st.z_model, st.z_measured, m.gflops));
    }
    Ok((t, rows))
}

/// A2: measure the effective B-reuse factor the ¼ heuristic
/// approximates. For each matrix: replay CSB's stream, subtract the
/// A-array and C compulsory traffic, and divide what remains (the
/// B-attributable DRAM bytes) by the unscaled `8·d·N·z` term.
pub fn ablate_reuse_factor(cfg: &ExperimentConfig, d: usize) -> Result<Table> {
    let mut t = Table::new(
        format!("A2 — effective CSB B-reuse factor vs the paper's 1/4 heuristic (d={d})"),
        &["Matrix", "8dNz MB (unscaled)", "sim B-traffic MB", "measured factor", "paper"],
    );
    for name in ["road_usa_p", "333sp_p", "er_18_10"] {
        let proxy = find(name).unwrap();
        let csr = proxy.generate(cfg.scale);
        let csb = Csb::from_csr(&csr);
        let st = BlockStats::of(&csb);
        let mut h = Hierarchy::new(HierarchyConfig::tiny());
        trace_csb_spmm(&csb, d, &mut h);
        let dram = h.report().dram_bytes as f64;
        // compulsory non-B traffic: A (12·nnz) + C write-back (8nd)
        let non_b = 12.0 * csr.nnz() as f64 + 8.0 * (csr.nrows * d) as f64;
        let b_traffic = (dram - non_b).max(0.0);
        let unscaled = 8.0 * d as f64 * st.n_blocks as f64 * st.z_model;
        let factor = if unscaled > 0.0 { b_traffic / unscaled } else { 0.0 };
        t.row(vec![
            name.to_string(),
            format!("{:.2}", unscaled / 1e6),
            format!("{:.2}", b_traffic / 1e6),
            format!("{factor:.3}"),
            "0.250".into(),
        ]);
    }
    Ok(t)
}

/// A3: thread-count sweep for the three native kernels on one matrix.
pub fn ablate_threads(
    cfg: &ExperimentConfig,
    matrix: &str,
    d: usize,
    threads: &[usize],
) -> Result<Table> {
    let proxy = find(matrix)
        .ok_or_else(|| crate::Error::Usage(format!("unknown proxy matrix '{matrix}'")))?;
    let csr = proxy.generate(cfg.scale);
    let mut t = Table::new(
        format!("A3 — thread scaling on {matrix} (d={d})"),
        &["threads", "CSR GF/s", "OPT GF/s", "CSB GF/s"],
    );
    for &p in threads {
        let csr_k = CsrSpmm::new(csr.clone(), p);
        let opt_k = OptSpmm::new(csr.clone(), p);
        let csb_k = CsbSpmm::from_csr(&csr, p);
        let g = |k: &dyn Spmm| -> Result<f64> {
            Ok(measure_kernel(k, d, cfg.iters, cfg.warmup)?.gflops)
        };
        t.row(vec![
            p.to_string(),
            format!("{:.3}", g(&csr_k)?),
            format!("{:.3}", g(&opt_k)?),
            format!("{:.3}", g(&csb_k)?),
        ]);
    }
    Ok(t)
}

/// The `z` model itself over a parameter grid (pure math — used by the
/// CLI's `ablate-z` to show where the Poisson approximation is loose).
pub fn z_model_grid() -> Table {
    let mut t = Table::new(
        "z = t(1 − e^{−D/t}) over (t, D)",
        &["t", "D=1", "D=8", "D=64", "D=512", "D=4096"],
    );
    for tt in [64usize, 256, 1024, 4096] {
        let mut row = vec![tt.to_string()];
        for dd in [1.0, 8.0, 64.0, 512.0, 4096.0] {
            row.push(format!("{:.1}", expected_z(tt as f64, dd)));
        }
        t.row(row);
    }
    t
}

/// Sanity: CSR vs simulated CSR traffic as d grows (supports the A2
/// interpretation: the simulator reproduces the d-scaling the random
/// model predicts).
pub fn traffic_vs_d(cfg: &ExperimentConfig, matrix: &str, ds: &[usize]) -> Result<Table> {
    let proxy = find(matrix)
        .ok_or_else(|| crate::Error::Usage(format!("unknown proxy matrix '{matrix}'")))?;
    let csr = proxy.generate(cfg.scale);
    let cls = crate::pattern::classify(&csr);
    let mut t = Table::new(
        format!("Simulated DRAM traffic vs d on {matrix}"),
        &["d", "model MB", "sim CSR MB", "ratio"],
    );
    for &d in ds {
        let model =
            cls.model.bytes(crate::model::AiParams::new(csr.nrows, d, csr.nnz()));
        let mut h = Hierarchy::new(HierarchyConfig::tiny());
        trace_csr_spmm(&csr, d, &mut h);
        let sim = h.report().dram_bytes as f64;
        t.row(vec![
            d.to_string(),
            format!("{:.2}", model / 1e6),
            format!("{:.2}", sim / 1e6),
            format!("{:.3}", sim / model),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            scale: 0.02,
            d_values: vec![4],
            threads: 1,
            iters: 1,
            warmup: 0,
            ..Default::default()
        }
    }

    #[test]
    fn block_sweep_reports_z() {
        let (t, rows) = ablate_block_size(&tiny_cfg(), "road_usa_p", 4, &[64, 256, 1024]).unwrap();
        assert_eq!(t.rows.len(), 3);
        for (bd, d_avg, z_model, z_meas, gf) in rows {
            assert!(bd > 0 && d_avg > 0.0 && gf > 0.0);
            // z estimates should agree within 2x on mesh-like matrices
            assert!(z_model / z_meas < 2.0 && z_meas / z_model < 2.0,
                "t={bd} z_model={z_model} z_meas={z_meas}");
        }
    }

    #[test]
    fn reuse_factor_is_sane() {
        let t = ablate_reuse_factor(&tiny_cfg(), 4).unwrap();
        assert_eq!(t.rows.len(), 3);
    }

    #[test]
    fn threads_sweep() {
        let t = ablate_threads(&tiny_cfg(), "er_18_10", 4, &[1, 2]).unwrap();
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn z_grid_limits() {
        let t = z_model_grid();
        // at t=64, D=4096 the block saturates: z == t
        assert_eq!(t.rows[0][5], "64.0");
    }

    #[test]
    fn traffic_vs_d_runs() {
        let t = traffic_vs_d(&tiny_cfg(), "er_18_1", &[1, 16]).unwrap();
        assert_eq!(t.rows.len(), 2);
    }
}

/// A4 (ours): reordering moves a matrix between structural regimes.
/// For each (matrix, ordering): classify, model AI, and measured OPT
/// GFLOP/s — the classifier and the measurement must move together.
pub fn ablate_reorder(cfg: &ExperimentConfig, d: usize) -> Result<Table> {
    use crate::sparse::reorder::{
        degree_sort, permute_symmetric, random_permutation, reverse_cuthill_mckee,
    };
    let mut t = Table::new(
        format!("A4 — reordering vs classification vs performance (OPT, d={d})"),
        &["Matrix", "Ordering", "Class", "AI@d", "OPT GF/s"],
    );
    let mut rng = crate::gen::Prng::new(0x07de5);
    for name in ["road_usa_p", "com_lj_p"] {
        let proxy = find(name).unwrap();
        let base = proxy.generate(cfg.scale);
        let orderings: Vec<(&str, crate::sparse::Csr)> = vec![
            ("natural", base.clone()),
            ("random", permute_symmetric(&base, &random_permutation(base.nrows, &mut rng))),
            ("rcm", permute_symmetric(&base, &reverse_cuthill_mckee(&base))),
            ("degree", permute_symmetric(&base, &degree_sort(&base))),
        ];
        for (oname, m) in orderings {
            let cls = crate::pattern::classify(&m);
            let ai = cls.model.ai(crate::model::AiParams::new(m.nrows, d, m.nnz()));
            let kernel = OptSpmm::new(m, cfg.threads);
            let g = measure_kernel(&kernel, d, cfg.iters, cfg.warmup)?.gflops;
            t.row(vec![
                name.to_string(),
                oname.to_string(),
                cls.class.to_string(),
                format!("{ai:.4}"),
                format!("{g:.3}"),
            ]);
        }
    }
    Ok(t)
}
