//! Shared measurement plumbing for the harness.

use std::sync::OnceLock;

use crate::gen::Prng;
use crate::membench;
use crate::metrics::{bench_adaptive, gflops, spmm_flops};
use crate::model::MachineParams;
use crate::spmm::{DenseMatrix, Spmm};

/// One measured (kernel, d) cell.
#[derive(Debug, Clone, Copy)]
pub struct CellMeasurement {
    pub d: usize,
    pub secs: f64,
    pub gflops: f64,
    /// Number of timed iterations behind the median.
    pub iters: usize,
}

/// Measure a prepared kernel at dense width `d`: median of an adaptive
/// benchmark loop (≥ `iters` iterations and ≥ 0.25 s of samples,
/// capped at 4×iters). B is seeded deterministically so every kernel
/// sees identical inputs.
pub fn measure_kernel(kernel: &dyn Spmm, d: usize, iters: usize, warmup: usize) -> CellMeasurement {
    let mut rng = Prng::new(0xB0B + d as u64);
    let b = DenseMatrix::random(kernel.ncols(), d, &mut rng);
    let mut c = DenseMatrix::zeros(kernel.nrows(), d);
    let r = bench_adaptive(warmup, iters, iters * 4, 0.25, |_| {
        kernel.execute(&b, &mut c).expect("kernel failed during measurement");
    });
    let secs = r.median_secs();
    CellMeasurement {
        d,
        secs,
        gflops: gflops(spmm_flops(kernel.nnz(), d), secs),
        iters: r.samples.len(),
    }
}

static MACHINE: OnceLock<MachineParams> = OnceLock::new();

/// Machine calibration (STREAM β + FMA π), measured once per process.
pub fn machine_params_cached(threads: usize) -> MachineParams {
    *MACHINE.get_or_init(|| membench::measure_machine(threads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, Prng};
    use crate::spmm::CsrSpmm;

    #[test]
    fn measure_kernel_positive() {
        let a = erdos_renyi(300, 300, 5.0, &mut Prng::new(190));
        let k = CsrSpmm::new(a, 1);
        let m = measure_kernel(&k, 8, 2, 0);
        assert!(m.gflops > 0.0);
        assert!(m.secs > 0.0);
        assert!(m.iters >= 2);
        assert_eq!(m.d, 8);
    }
}
