//! Shared measurement plumbing for the harness.

use std::sync::OnceLock;

use crate::error::Result;
use crate::gen::Prng;
use crate::membench;
use crate::metrics::{bench_adaptive_checked, gflops, spmm_flops};
use crate::model::MachineParams;
use crate::spmm::{DenseMatrix, Spmm};

/// One measured (kernel, d) cell.
#[derive(Debug, Clone, Copy)]
pub struct CellMeasurement {
    pub d: usize,
    pub secs: f64,
    pub gflops: f64,
    /// Number of timed iterations behind the median.
    pub iters: usize,
}

/// Measure a prepared kernel at dense width `d`: median of an adaptive
/// benchmark loop (≥ `iters` iterations and ≥ 0.25 s of samples,
/// capped at 4×iters). B is seeded deterministically so every kernel
/// sees identical inputs.
///
/// A failing kernel surfaces as `Err` — before *and* mid-way through
/// the timing loop. An earlier revision `expect`ed inside the loop, so
/// one flaky kernel panicked the measurement through the shared worker
/// pool instead of failing its own cell (regression-tested below).
pub fn measure_kernel(
    kernel: &dyn Spmm,
    d: usize,
    iters: usize,
    warmup: usize,
) -> Result<CellMeasurement> {
    let mut rng = Prng::new(0xB0B + d as u64);
    let b = DenseMatrix::random(kernel.ncols(), d, &mut rng);
    let mut c = DenseMatrix::zeros(kernel.nrows(), d);
    // surface errors before the timed region
    kernel.execute(&b, &mut c)?;
    let r =
        bench_adaptive_checked(warmup, iters, iters * 4, 0.25, |_| kernel.execute(&b, &mut c))?;
    let secs = r.median_secs();
    Ok(CellMeasurement {
        d,
        secs,
        gflops: gflops(spmm_flops(kernel.nnz(), d), secs),
        iters: r.samples.len(),
    })
}

static MACHINE: OnceLock<MachineParams> = OnceLock::new();

/// Machine calibration (STREAM β + FMA π), measured once per process.
pub fn machine_params_cached(threads: usize) -> MachineParams {
    *MACHINE.get_or_init(|| membench::measure_machine(threads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;
    use crate::gen::{erdos_renyi, Prng};
    use crate::spmm::{CsrSpmm, Impl};
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn measure_kernel_positive() {
        let a = erdos_renyi(300, 300, 5.0, &mut Prng::new(190));
        let k = CsrSpmm::new(a, 1);
        let m = measure_kernel(&k, 8, 2, 0).unwrap();
        assert!(m.gflops > 0.0);
        assert!(m.secs > 0.0);
        assert!(m.iters >= 2);
        assert_eq!(m.d, 8);
    }

    /// Fails after `ok_calls` successful executions — exercises both
    /// the pre-loop check and the mid-loop capture.
    struct Flaky {
        calls: AtomicUsize,
        ok_calls: usize,
    }

    impl Spmm for Flaky {
        fn id(&self) -> Impl {
            Impl::Csr
        }
        fn nrows(&self) -> usize {
            4
        }
        fn ncols(&self) -> usize {
            4
        }
        fn nnz(&self) -> usize {
            4
        }
        fn execute(&self, _b: &DenseMatrix, _c: &mut DenseMatrix) -> Result<()> {
            if self.calls.fetch_add(1, Ordering::SeqCst) < self.ok_calls {
                Ok(())
            } else {
                Err(Error::InvalidStructure("flaky kernel".into()))
            }
        }
    }

    #[test]
    fn failing_kernel_surfaces_err_not_panic() {
        // fails immediately: caught by the pre-loop check
        let k = Flaky { calls: AtomicUsize::new(0), ok_calls: 0 };
        assert!(measure_kernel(&k, 4, 2, 0).is_err());
        // fails mid-loop: the old `expect` panicked here
        let k = Flaky { calls: AtomicUsize::new(0), ok_calls: 1 };
        assert!(measure_kernel(&k, 4, 2, 0).is_err());
        // and the shared pool is not poisoned: a healthy kernel still
        // measures fine afterwards
        let a = erdos_renyi(100, 100, 3.0, &mut Prng::new(191));
        let real = CsrSpmm::new(a, 2);
        assert!(measure_kernel(&real, 4, 1, 0).unwrap().gflops > 0.0);
    }
}
