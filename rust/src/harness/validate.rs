//! V1 (ours): validate the analytic AI models against *simulated*
//! memory traffic.
//!
//! For each representative matrix, the exact CSR and CSB access
//! streams are replayed through the cache-hierarchy simulator; the
//! resulting DRAM byte count is compared with the class model's byte
//! denominator (Eqs. 2/3/4/6). This separates "model error" from
//! "implementation inefficiency" — the confound the paper's
//! limitations section (§V) concedes it cannot untangle from timing
//! alone.

use crate::cachesim::{trace_spmm_batch, HierarchyConfig, TraceJob};
use crate::config::ExperimentConfig;
use crate::error::Result;
use crate::gen::{representative_suite, SparsityClass};
use crate::model::AiParams;
use crate::pattern::classify;
use crate::report::{write_csv, Table};
use crate::sparse::Csb;

/// One validation row: modeled vs simulated bytes.
#[derive(Debug, Clone)]
pub struct ValidationRow {
    pub matrix: String,
    pub class: SparsityClass,
    pub d: usize,
    pub n: usize,
    pub nnz: usize,
    /// Class-model byte denominator.
    pub model_bytes: f64,
    /// Simulated DRAM bytes of the CSR kernel's stream.
    pub sim_csr_bytes: u64,
    /// Simulated DRAM bytes of the CSB kernel's stream.
    pub sim_csb_bytes: u64,
}

impl ValidationRow {
    /// simulated / modeled for CSR — 1.0 means the analytic model
    /// matches the simulated hierarchy exactly.
    pub fn csr_ratio(&self) -> f64 {
        self.sim_csr_bytes as f64 / self.model_bytes
    }
    pub fn csb_ratio(&self) -> f64 {
        self.sim_csb_bytes as f64 / self.model_bytes
    }
}

/// Run the validation at a reduced scale (the simulator replays every
/// access; keep `cfg.scale` small — the CLI defaults this experiment
/// to scale/8). The hierarchy is the `tiny` config so that `B` exceeds
/// the simulated L3 at the reduced matrix sizes — the same
/// "matrices exceed on-chip cache" regime the paper enforces (§IV-A)
/// at full scale.
pub fn run_validate_ai(cfg: &ExperimentConfig) -> Result<Vec<ValidationRow>> {
    let mut rows = Vec::new();
    // one matrix live at a time (full-scale proxies are large); its
    // 2·|d| replay jobs still fan out across the persistent pool
    for proxy in representative_suite() {
        let csr = proxy.generate(cfg.scale);
        let cls = classify(&csr);
        let csb = Csb::from_csr(&csr);
        let mut jobs = Vec::new();
        for &d in &cfg.d_values {
            jobs.push(TraceJob::Csr(&csr, d));
            jobs.push(TraceJob::Csb(&csb, d));
        }
        let reports = trace_spmm_batch(&jobs, HierarchyConfig::tiny());
        for (i, &d) in cfg.d_values.iter().enumerate() {
            let p = AiParams::new(csr.nrows, d, csr.nnz());
            rows.push(ValidationRow {
                matrix: proxy.name.to_string(),
                class: proxy.class,
                d,
                n: csr.nrows,
                nnz: csr.nnz(),
                model_bytes: cls.model.bytes(p),
                sim_csr_bytes: reports[2 * i].dram_bytes,
                sim_csb_bytes: reports[2 * i + 1].dram_bytes,
            });
        }
    }
    Ok(rows)
}

/// Render validation rows.
pub fn render(rows: &[ValidationRow]) -> Table {
    let mut t = Table::new(
        "V1 — analytic model bytes vs simulated DRAM bytes (LRU L1/L2/L3)",
        &["Matrix", "Class", "d", "Model MB", "Sim CSR MB", "Sim CSB MB", "CSR/Model", "CSB/Model"],
    );
    for r in rows {
        t.row(vec![
            r.matrix.clone(),
            r.class.to_string(),
            r.d.to_string(),
            format!("{:.2}", r.model_bytes / 1e6),
            format!("{:.2}", r.sim_csr_bytes as f64 / 1e6),
            format!("{:.2}", r.sim_csb_bytes as f64 / 1e6),
            format!("{:.2}", r.csr_ratio()),
            format!("{:.2}", r.csb_ratio()),
        ]);
    }
    t
}

/// CSV output.
pub fn save_csv(rows: &[ValidationRow], path: &str) -> Result<()> {
    let data: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.matrix.clone(),
                r.class.to_string(),
                r.d.to_string(),
                r.n.to_string(),
                r.nnz.to_string(),
                format!("{:.0}", r.model_bytes),
                r.sim_csr_bytes.to_string(),
                r.sim_csb_bytes.to_string(),
                format!("{:.4}", r.csr_ratio()),
                format!("{:.4}", r.csb_ratio()),
            ]
        })
        .collect();
    write_csv(
        path,
        &["matrix", "class", "d", "n", "nnz", "model_bytes", "sim_csr_bytes", "sim_csb_bytes", "csr_ratio", "csb_ratio"],
        &data,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation_orders_hold() {
        let cfg = ExperimentConfig {
            scale: 0.02,
            d_values: vec![16],
            threads: 1,
            iters: 1,
            warmup: 0,
            ..Default::default()
        };
        let rows = run_validate_ai(&cfg).unwrap();
        assert_eq!(rows.len(), 4);
        let by_name = |n: &str| rows.iter().find(|r| r.matrix == n).unwrap();
        let er = by_name("er_18_1");
        let diag = by_name("rajat31_p");
        // the random model is a worst case: simulated traffic must not
        // exceed it by much (allow simulator conflict-miss slack)
        assert!(er.csr_ratio() < 1.4, "er ratio {}", er.csr_ratio());
        // diagonal: the model is an optimistic lower bound on traffic —
        // simulation can only exceed it
        assert!(diag.csr_ratio() > 0.8, "diag ratio {}", diag.csr_ratio());
        let t = render(&rows);
        assert_eq!(t.rows.len(), 4);
    }
}
