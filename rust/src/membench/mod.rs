//! Machine calibration: a STREAM port for `β` and an FMA peak loop for
//! `π`.
//!
//! The paper measured `β = 122.6 GB/s` with McCalpin's STREAM on one
//! EPYC-7763 socket (§IV-B) and used it as the roofline's bandwidth
//! ceiling. This module reimplements the four STREAM kernels (Copy,
//! Scale, Add, Triad) plus a peak-FLOP microbenchmark so the roofline
//! is calibrated to *this* testbed.

//! A second, deeper calibration lives in [`calib`]: a per-cache-level
//! read/write/triad sweep plus a width-aware FMA peak probe producing
//! a [`MeasuredLadder`] the planner prefers over the nominal prior.

mod calib;
mod stream;

pub use calib::{calibrate, calibrate_with, CalibConfig, LadderLevel, MeasuredLadder};
pub use stream::{
    bandwidth_ladder, cache_levels, measure_machine, peak_flops_gflops, stream_benchmark,
    StreamResult,
};
