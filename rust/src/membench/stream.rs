//! STREAM (Copy/Scale/Add/Triad) and peak-FLOP microbenchmarks.

use crate::metrics::Timer;
use crate::model::MachineParams;
use crate::spmm::pool::parallel_ranges;

/// Per-kernel best bandwidth in GB/s, STREAM-style (best of `reps`).
#[derive(Debug, Clone, Copy)]
pub struct StreamResult {
    pub copy_gbs: f64,
    pub scale_gbs: f64,
    pub add_gbs: f64,
    pub triad_gbs: f64,
    /// Array length used (elements per array).
    pub len: usize,
}

impl StreamResult {
    /// The bandwidth the roofline uses — STREAM convention is to quote
    /// Triad; we follow the paper and take the peak across kernels.
    pub fn beta_gbs(&self) -> f64 {
        self.copy_gbs.max(self.scale_gbs).max(self.add_gbs).max(self.triad_gbs)
    }
}

fn touch(x: f64) {
    // prevent the optimizer from deleting benchmark loops
    unsafe { std::ptr::read_volatile(&x) };
}

/// Run the four STREAM kernels over arrays of `len` f64s with
/// `threads` workers, `reps` timed repetitions each (best-of).
/// STREAM's rule of thumb: `len` ≥ 4× the largest cache.
pub fn stream_benchmark(len: usize, threads: usize, reps: usize) -> StreamResult {
    let mut a = vec![1.0f64; len];
    let mut b = vec![2.0f64; len];
    let mut c = vec![0.0f64; len];
    let scalar = 3.0f64;

    // RawParts lets scoped threads write disjoint ranges.
    struct Raw(*mut f64);
    unsafe impl Send for Raw {}
    unsafe impl Sync for Raw {}

    let mut best = [f64::INFINITY; 4];
    for _ in 0..reps.max(1) {
        // Copy: c = a          (2 arrays moved)
        let t = Timer::start();
        {
            let (ap, cp) = (Raw(a.as_mut_ptr()), Raw(c.as_mut_ptr()));
            parallel_ranges(len, threads, |r| {
                let (ap, cp) = (&ap, &cp);
                unsafe {
                    for i in r {
                        *cp.0.add(i) = *ap.0.add(i);
                    }
                }
            });
        }
        best[0] = best[0].min(t.elapsed_secs());

        // Scale: b = s*c       (2 arrays)
        let t = Timer::start();
        {
            let (bp, cp) = (Raw(b.as_mut_ptr()), Raw(c.as_mut_ptr()));
            parallel_ranges(len, threads, |r| {
                let (bp, cp) = (&bp, &cp);
                unsafe {
                    for i in r {
                        *bp.0.add(i) = scalar * *cp.0.add(i);
                    }
                }
            });
        }
        best[1] = best[1].min(t.elapsed_secs());

        // Add: c = a + b       (3 arrays)
        let t = Timer::start();
        {
            let (ap, bp, cp) = (Raw(a.as_mut_ptr()), Raw(b.as_mut_ptr()), Raw(c.as_mut_ptr()));
            parallel_ranges(len, threads, |r| {
                let (ap, bp, cp) = (&ap, &bp, &cp);
                unsafe {
                    for i in r {
                        *cp.0.add(i) = *ap.0.add(i) + *bp.0.add(i);
                    }
                }
            });
        }
        best[2] = best[2].min(t.elapsed_secs());

        // Triad: a = b + s*c   (3 arrays)
        let t = Timer::start();
        {
            let (ap, bp, cp) = (Raw(a.as_mut_ptr()), Raw(b.as_mut_ptr()), Raw(c.as_mut_ptr()));
            parallel_ranges(len, threads, |r| {
                let (ap, bp, cp) = (&ap, &bp, &cp);
                unsafe {
                    for i in r {
                        *ap.0.add(i) = *bp.0.add(i) + scalar * *cp.0.add(i);
                    }
                }
            });
        }
        best[3] = best[3].min(t.elapsed_secs());
    }
    touch(a[len / 2] + b[len / 3] + c[len / 7]);

    let gb = |arrays: f64, secs: f64| arrays * len as f64 * 8.0 / secs / 1e9;
    StreamResult {
        copy_gbs: gb(2.0, best[0]),
        scale_gbs: gb(2.0, best[1]),
        add_gbs: gb(3.0, best[2]),
        triad_gbs: gb(3.0, best[3]),
        len,
    }
}

/// Peak FP64 GFLOP/s estimate: independent FMA chains over registers,
/// fully unrolled, one work item per requested thread. This measures
/// the *practical* compute roof the roofline's `π` needs (SpMM never
/// gets near it — the point of measuring is to place the ridge).
///
/// Timed as wall clock around the whole parallel loop: every work
/// item executes exactly once regardless of how many pool
/// participants the job gets, so if the pool is smaller than
/// `threads` the serialised items lengthen the wall time and `π`
/// stays honest (a per-item timer would see uncontended solo runs and
/// inflate it).
pub fn peak_flops_gflops(threads: usize) -> f64 {
    const ITERS: usize = 4_000_000;
    const CHAINS: usize = 8;
    let threads = threads.max(1);
    let t = Timer::start();
    parallel_ranges(threads, threads, |_| {
        let mut acc = [1.000001f64; CHAINS];
        let x = 1.0000001f64;
        let y = 0.9999999f64;
        for _ in 0..ITERS {
            for a in acc.iter_mut() {
                *a = a.mul_add(x, y);
            }
        }
        touch(acc.iter().sum());
    });
    let secs = t.elapsed_secs();
    let flops = (threads * ITERS * CHAINS * 2) as f64;
    flops / secs / 1e9
}

/// Calibrate the roofline's machine parameters on this host:
/// `β` from STREAM (best kernel), `π` from the FMA loop.
pub fn measure_machine(threads: usize) -> MachineParams {
    // 32 MiB arrays — beyond any cache on this box, quick to run
    let s = stream_benchmark(4 << 20, threads, 3);
    MachineParams { beta_gbs: s.beta_gbs(), pi_gflops: peak_flops_gflops(threads) }
}

/// The data-cache levels of this host as `(name, capacity_bytes)`
/// pairs, ordered by capacity ascending — read from `/sys` (cpu0)
/// with typical defaults when that's absent. Cheap (no measurement):
/// shared by the measured [`bandwidth_ladder`] and the calibration-free
/// `model::CacheAwareRoofline::nominal`.
pub fn cache_levels() -> Vec<(String, usize)> {
    let read_kb = |path: &str| -> Option<usize> {
        let s = std::fs::read_to_string(path).ok()?;
        s.trim().trim_end_matches('K').parse::<usize>().ok()
    };
    let base = "/sys/devices/system/cpu/cpu0/cache";
    let mut levels: Vec<(String, usize)> = Vec::new();
    for idx in 0..5 {
        let level = std::fs::read_to_string(format!("{base}/index{idx}/level"))
            .map(|s| s.trim().to_string())
            .unwrap_or_default();
        let typ = std::fs::read_to_string(format!("{base}/index{idx}/type"))
            .map(|s| s.trim().to_string())
            .unwrap_or_default();
        if typ == "Instruction" || level.is_empty() {
            continue;
        }
        if let Some(kb) = read_kb(&format!("{base}/index{idx}/size")) {
            levels.push((format!("L{level}"), kb << 10));
        }
    }
    if levels.is_empty() {
        // sensible defaults when /sys is absent
        levels = vec![("L1".into(), 32 << 10), ("L2".into(), 1 << 20), ("L3".into(), 16 << 20)];
    }
    levels.sort_by_key(|&(_, cap)| cap);
    levels.dedup_by_key(|(_, cap)| *cap);
    levels
}

/// Measure the bandwidth *ladder* for the cache-aware roofline
/// (`model::CacheAwareRoofline`): STREAM triad at working sets sized
/// for each cache level reported by the OS, plus a beyond-cache DRAM
/// point. Returns ceilings ordered by capacity.
pub fn bandwidth_ladder(threads: usize) -> Vec<crate::model::BandwidthCeiling> {
    use crate::model::BandwidthCeiling;
    let levels = cache_levels();

    let mut out = Vec::new();
    for (name, cap) in &levels {
        // three arrays must fit in the level: len = cap / (3 arrays × 8B) / 2 headroom
        let len = (cap / (3 * 8 * 2)).max(1 << 10);
        let s = stream_benchmark(len, threads, 5);
        out.push(BandwidthCeiling {
            level: name.clone(),
            capacity_bytes: *cap,
            beta_gbs: s.triad_gbs,
        });
    }
    // DRAM: 4× the largest cache
    let dram_len = (levels.last().unwrap().1 * 4 / 8).max(4 << 20);
    let s = stream_benchmark(dram_len.min(64 << 20), threads, 2);
    out.push(BandwidthCeiling {
        level: "DRAM".into(),
        capacity_bytes: usize::MAX,
        beta_gbs: s.triad_gbs,
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_produces_positive_bandwidth() {
        let r = stream_benchmark(1 << 18, 1, 1);
        for g in [r.copy_gbs, r.scale_gbs, r.add_gbs, r.triad_gbs] {
            assert!(g > 0.1 && g < 10_000.0, "{g}");
        }
        assert!(r.beta_gbs() >= r.triad_gbs);
    }

    #[test]
    fn peak_flops_positive() {
        let p = peak_flops_gflops(1);
        assert!(p > 0.1 && p < 10_000.0, "{p}");
    }

    #[test]
    fn measure_machine_fields() {
        let m = measure_machine(1);
        assert!(m.beta_gbs > 0.0);
        assert!(m.pi_gflops > 0.0);
        assert!(m.ridge_ai() > 0.0);
    }
}
