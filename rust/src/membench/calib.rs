//! The measured calibration path: a per-cache-level read/write/triad
//! bandwidth sweep plus a width-aware FMA peak probe, packaged as a
//! [`MeasuredLadder`] the planner consumes *in preference to* the
//! calibration-free `CacheAwareRoofline::nominal` prior.
//!
//! `nominal` guesses each level's bandwidth as DRAM `β` scaled by
//! conventional 2×-per-level multipliers; this module measures it. The
//! sweep runs three kernels per level at a working set sized to sit
//! inside that level:
//!
//! * **read** — a sum reduction (1 array in, nothing out): the pure
//!   load bandwidth an SpMM `B`-panel gather is bounded by,
//! * **write** — a fill (1 array out): the `C`-zeroing / spill-phase
//!   store bandwidth,
//! * **triad** — STREAM Triad `a = b + s·c` (3 arrays): the mixed
//!   pattern the flat STREAM calibration quotes.
//!
//! The peak probe chains independent FMAs as wide as the dispatched
//! micro-kernel tier ([`crate::spmm::simd::level`]): `_mm256_fmadd_pd`
//! over 4 f64 lanes when AVX+FMA are live, the scalar `mul_add` chain
//! otherwise — so `π` reflects the ISA the kernels actually run, not
//! an abstract nameplate.
//!
//! Calibration is seconds of wall time, so the result is persisted in
//! the autotune snapshot ([`crate::report::AutotuneState`]) and a
//! restarted engine installs it without re-measuring — exactly as it
//! skips re-exploration. `MODELS.md` §7 derives how the substitution
//! moves each prediction term; the `calib` CLI command prints the
//! measured-vs-nominal-vs-cachesim cross-validation table.

use crate::membench::cache_levels;
use crate::metrics::Timer;
use crate::model::{BandwidthCeiling, CacheAwareRoofline};
use crate::spmm::pool::parallel_ranges;
use crate::spmm::simd;

/// One measured rung: a named memory level with its capacity and the
/// three per-kernel bandwidths. The DRAM rung carries
/// `capacity_bytes == usize::MAX`.
#[derive(Debug, Clone, PartialEq)]
pub struct LadderLevel {
    pub level: String,
    pub capacity_bytes: usize,
    pub read_gbs: f64,
    pub write_gbs: f64,
    pub triad_gbs: f64,
}

impl LadderLevel {
    /// The bandwidth the roofline uses for this rung — the paper's
    /// convention (`StreamResult::beta_gbs`) of quoting the best
    /// kernel, since each model term is bounded by the pattern that
    /// dominates it.
    pub fn beta_gbs(&self) -> f64 {
        self.read_gbs.max(self.write_gbs).max(self.triad_gbs)
    }
}

/// A fully measured bandwidth/peak ladder: what
/// [`CacheAwareRoofline::nominal`] guesses, measured. Built by
/// [`calibrate`], installed into the planner
/// (`coordinator::Planner::install_measured`), and persisted in the
/// autotune snapshot so restarts skip the sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct MeasuredLadder {
    /// Rungs ordered by capacity ascending, DRAM last.
    pub levels: Vec<LadderLevel>,
    /// Measured compute roof (GFLOP/s) from the width-aware FMA probe.
    pub peak_gflops: f64,
    /// The dispatched micro-kernel tier the probe ran at
    /// ([`crate::spmm::simd::SimdLevel::name`]).
    pub simd_level: String,
    /// Worker threads the sweep ran with.
    pub threads: usize,
}

impl MeasuredLadder {
    /// The roofline ladder the planner consumes. Mirrors the `nominal`
    /// construction so the two are drop-in interchangeable: cache
    /// capacities are halved as the effective residency threshold
    /// (a working set at nominal capacity thrashes against the
    /// kernel's other streams), DRAM keeps `usize::MAX`, and `π` is
    /// the measured peak.
    pub fn to_roofline(&self) -> CacheAwareRoofline {
        assert!(!self.levels.is_empty());
        let ceilings = self
            .levels
            .iter()
            .map(|l| BandwidthCeiling {
                level: l.level.clone(),
                capacity_bytes: if l.capacity_bytes == usize::MAX {
                    usize::MAX
                } else {
                    (l.capacity_bytes / 2).max(1)
                },
                beta_gbs: l.beta_gbs(),
            })
            .collect();
        CacheAwareRoofline::new(ceilings, self.peak_gflops)
    }

    /// The flat machine parameters this ladder degenerates to (DRAM β,
    /// measured π) — usable anywhere a `MachineParams` is.
    pub fn flat(&self) -> crate::model::MachineParams {
        crate::model::MachineParams {
            beta_gbs: self.levels.last().map(|l| l.beta_gbs()).unwrap_or(0.0),
            pi_gflops: self.peak_gflops,
        }
    }
}

/// Knobs for the sweep — the defaults are the real calibration; CI
/// smoke runs pass tiny values so the job finishes in milliseconds.
#[derive(Debug, Clone, Copy)]
pub struct CalibConfig {
    /// Timed repetitions per kernel per level (best-of).
    pub reps: usize,
    /// Cap on elements per array (bounds the DRAM rung's footprint).
    pub max_len: usize,
    /// Iterations per FMA chain in the peak probe.
    pub peak_iters: usize,
}

impl Default for CalibConfig {
    fn default() -> Self {
        CalibConfig { reps: 5, max_len: 64 << 20, peak_iters: 4_000_000 }
    }
}

fn touch(x: f64) {
    unsafe { std::ptr::read_volatile(&x) };
}

// RawParts shim: scoped pool workers write disjoint ranges.
struct Raw(*mut f64);
unsafe impl Send for Raw {}
unsafe impl Sync for Raw {}

/// Best-of-`reps` seconds for one timed closure.
fn best_of<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t = Timer::start();
        f();
        best = best.min(t.elapsed_secs());
    }
    best
}

/// Measure read (sum-reduce), write (fill) and triad bandwidth over
/// arrays of `len` f64 elements.
fn sweep_level(len: usize, threads: usize, reps: usize) -> (f64, f64, f64) {
    let mut a = vec![1.0f64; len];
    let mut b = vec![2.0f64; len];
    let mut c = vec![0.5f64; len];
    let scalar = 3.0f64;

    // read: 1 array of traffic
    let tr = best_of(reps, || {
        let ap = Raw(a.as_mut_ptr());
        parallel_ranges(len, threads, |r| {
            let ap = &ap;
            let mut acc = 0.0f64;
            unsafe {
                for i in r {
                    acc += *ap.0.add(i);
                }
            }
            touch(acc);
        });
    });

    // write: 1 array of traffic
    let tw = best_of(reps, || {
        let cp = Raw(c.as_mut_ptr());
        parallel_ranges(len, threads, |r| {
            let cp = &cp;
            unsafe {
                for i in r {
                    *cp.0.add(i) = 0.25;
                }
            }
        });
    });

    // triad: a = b + s·c, 3 arrays of traffic
    let tt = best_of(reps, || {
        let (ap, bp, cp) = (Raw(a.as_mut_ptr()), Raw(b.as_mut_ptr()), Raw(c.as_mut_ptr()));
        parallel_ranges(len, threads, |r| {
            let (ap, bp, cp) = (&ap, &bp, &cp);
            unsafe {
                for i in r {
                    *ap.0.add(i) = *bp.0.add(i) + scalar * *cp.0.add(i);
                }
            }
        });
    });
    touch(a[len / 2] + b[len / 3] + c[len / 7]);

    let gb = |arrays: f64, secs: f64| arrays * len as f64 * 8.0 / secs / 1e9;
    (gb(1.0, tr), gb(1.0, tw), gb(3.0, tt))
}

/// FMA chains per work item in the peak probe — enough independent
/// accumulators to cover FMA latency × issue width.
const CHAINS: usize = 8;

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx,fma")]
unsafe fn peak_item_avx_fma(iters: usize) -> f64 {
    use std::arch::x86_64::*;
    let x = _mm256_set1_pd(1.0000001);
    let y = _mm256_set1_pd(0.9999999);
    let mut acc = [_mm256_set1_pd(1.000001); CHAINS];
    for _ in 0..iters {
        for a in acc.iter_mut() {
            *a = _mm256_fmadd_pd(*a, x, y);
        }
    }
    let mut total = _mm256_setzero_pd();
    for a in acc {
        total = _mm256_add_pd(total, a);
    }
    let mut out = [0.0f64; 4];
    _mm256_storeu_pd(out.as_mut_ptr(), total);
    out.iter().sum()
}

fn peak_item_scalar(iters: usize) -> f64 {
    let mut acc = [1.000001f64; CHAINS];
    let x = 1.0000001f64;
    let y = 0.9999999f64;
    for _ in 0..iters {
        for a in acc.iter_mut() {
            *a = a.mul_add(x, y);
        }
    }
    acc.iter().sum()
}

/// Width-aware peak probe: FMA chains as wide as the dispatched
/// micro-kernel tier allows. Returns (GFLOP/s, lanes used). Timed as
/// wall clock around the whole parallel loop so a pool smaller than
/// `threads` cannot inflate the result.
fn peak_probe(threads: usize, iters: usize) -> (f64, usize) {
    let threads = threads.max(1);
    #[cfg(target_arch = "x86_64")]
    let lanes = if simd::level() != simd::SimdLevel::Scalar
        && is_x86_feature_detected!("avx")
        && is_x86_feature_detected!("fma")
    {
        4
    } else {
        1
    };
    #[cfg(not(target_arch = "x86_64"))]
    let lanes = 1;

    let t = Timer::start();
    parallel_ranges(threads, threads, |_| {
        #[cfg(target_arch = "x86_64")]
        // safety: lanes == 4 only after both features were detected
        let s = if lanes == 4 { unsafe { peak_item_avx_fma(iters) } } else { peak_item_scalar(iters) };
        #[cfg(not(target_arch = "x86_64"))]
        let s = peak_item_scalar(iters);
        touch(s);
    });
    let secs = t.elapsed_secs();
    let flops = (threads * iters * CHAINS * lanes * 2) as f64;
    (flops / secs / 1e9, lanes)
}

/// Run the full measured calibration with custom knobs.
pub fn calibrate_with(threads: usize, cfg: CalibConfig) -> MeasuredLadder {
    let threads = threads.max(1);
    let host = cache_levels();
    let mut levels = Vec::with_capacity(host.len() + 1);
    for (name, cap) in &host {
        // three arrays must fit the level with 2× headroom, same
        // sizing rule as membench::bandwidth_ladder
        let len = (cap / (3 * 8 * 2)).max(1 << 10).min(cfg.max_len);
        let (read, write, triad) = sweep_level(len, threads, cfg.reps);
        levels.push(LadderLevel {
            level: name.clone(),
            capacity_bytes: *cap,
            read_gbs: read,
            write_gbs: write,
            triad_gbs: triad,
        });
    }
    // DRAM rung: 4× the largest cache, capped
    let dram_len = (host.last().map(|&(_, c)| c).unwrap_or(16 << 20) * 4 / 8)
        .max(1 << 20)
        .min(cfg.max_len);
    let (read, write, triad) = sweep_level(dram_len, threads, cfg.reps.min(2));
    levels.push(LadderLevel {
        level: "DRAM".into(),
        capacity_bytes: usize::MAX,
        read_gbs: read,
        write_gbs: write,
        triad_gbs: triad,
    });

    let (peak_gflops, _lanes) = peak_probe(threads, cfg.peak_iters);
    MeasuredLadder {
        levels,
        peak_gflops,
        simd_level: simd::level().name().to_string(),
        threads,
    }
}

/// Run the full measured calibration with default knobs (seconds of
/// wall time — persist the result).
pub fn calibrate(threads: usize) -> MeasuredLadder {
    calibrate_with(threads, CalibConfig::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CalibConfig {
        CalibConfig { reps: 1, max_len: 1 << 12, peak_iters: 10_000 }
    }

    #[test]
    fn calibrate_covers_every_host_level_plus_dram() {
        let ml = calibrate_with(1, tiny());
        assert_eq!(ml.levels.len(), cache_levels().len() + 1);
        assert_eq!(ml.levels.last().unwrap().level, "DRAM");
        assert_eq!(ml.levels.last().unwrap().capacity_bytes, usize::MAX);
        for l in &ml.levels {
            assert!(l.read_gbs > 0.0 && l.read_gbs < 1e6, "{}: {}", l.level, l.read_gbs);
            assert!(l.write_gbs > 0.0 && l.write_gbs < 1e6);
            assert!(l.triad_gbs > 0.0 && l.triad_gbs < 1e6);
            assert!(l.beta_gbs() >= l.triad_gbs);
        }
        assert!(ml.peak_gflops > 0.0 && ml.peak_gflops < 1e6);
        assert!(crate::spmm::simd::SimdLevel::parse(&ml.simd_level).is_some());
        assert_eq!(ml.threads, 1);
    }

    #[test]
    fn to_roofline_mirrors_nominal_shape() {
        let ml = MeasuredLadder {
            levels: vec![
                LadderLevel {
                    level: "L1".into(),
                    capacity_bytes: 32 << 10,
                    read_gbs: 300.0,
                    write_gbs: 200.0,
                    triad_gbs: 280.0,
                },
                LadderLevel {
                    level: "DRAM".into(),
                    capacity_bytes: usize::MAX,
                    read_gbs: 20.0,
                    write_gbs: 15.0,
                    triad_gbs: 22.0,
                },
            ],
            peak_gflops: 90.0,
            simd_level: "avx".into(),
            threads: 4,
        };
        let r = ml.to_roofline();
        assert_eq!(r.ceilings.len(), 2);
        // capacity halved as the residency threshold, DRAM untouched
        assert_eq!(r.ceilings[0].capacity_bytes, 16 << 10);
        assert_eq!(r.ceilings[1].capacity_bytes, usize::MAX);
        // best-of-kernels bandwidth per rung
        assert_eq!(r.ceilings[0].beta_gbs, 300.0);
        assert_eq!(r.ceilings[1].beta_gbs, 22.0);
        assert_eq!(r.pi_gflops, 90.0);
        assert_eq!(ml.flat().beta_gbs, 22.0);
        assert_eq!(ml.flat().pi_gflops, 90.0);
    }
}
