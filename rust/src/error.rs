//! Crate-wide error type (hand-rolled `Display`/`Error` impls — the
//! crate builds offline with no proc-macro dependencies).

use std::fmt;

/// Errors produced by the spmm-roofline library.
#[derive(Debug)]
pub enum Error {
    /// Dimension mismatch between operands (e.g. `A.cols != B.rows`).
    DimensionMismatch(String),

    /// A sparse structure failed validation (unsorted/out-of-range
    /// indices, broken row pointers, ...).
    InvalidStructure(String),

    /// Error parsing an external format (MatrixMarket, TOML-lite,
    /// manifest JSON).
    Parse(String),

    /// Invalid configuration value.
    Config(String),

    /// The requested artifact is missing from `artifacts/` — run
    /// `make artifacts` first.
    MissingArtifact(String),

    /// An error surfaced by the XLA/PJRT runtime (or its stub when the
    /// `xla` feature is off).
    Xla(String),

    /// Unknown CLI command / bad CLI usage.
    Usage(String),

    /// A kernel panicked mid-execution; the panic was contained at the
    /// serving layer and converted into this error so one poisoned job
    /// (or coalesced batch) cannot take down the worker pool.
    Panic(String),

    /// Underlying IO error.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::DimensionMismatch(s) => write!(f, "dimension mismatch: {s}"),
            Error::InvalidStructure(s) => write!(f, "invalid sparse structure: {s}"),
            Error::Parse(s) => write!(f, "parse error: {s}"),
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::MissingArtifact(s) => write!(f, "missing artifact: {s} (run `make artifacts`)"),
            Error::Xla(s) => write!(f, "xla runtime error: {s}"),
            Error::Usage(s) => write!(f, "usage error: {s}"),
            Error::Panic(s) => write!(f, "kernel panic: {s}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert_eq!(
            Error::DimensionMismatch("a".into()).to_string(),
            "dimension mismatch: a"
        );
        assert_eq!(Error::Xla("x".into()).to_string(), "xla runtime error: x");
        assert!(Error::MissingArtifact("f".into()).to_string().contains("make artifacts"));
    }

    #[test]
    fn io_source_preserved() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().contains("gone"));
    }
}
