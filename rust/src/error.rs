//! Crate-wide error type.

use thiserror::Error;

/// Errors produced by the spmm-roofline library.
#[derive(Error, Debug)]
pub enum Error {
    /// Dimension mismatch between operands (e.g. `A.cols != B.rows`).
    #[error("dimension mismatch: {0}")]
    DimensionMismatch(String),

    /// A sparse structure failed validation (unsorted/out-of-range
    /// indices, broken row pointers, ...).
    #[error("invalid sparse structure: {0}")]
    InvalidStructure(String),

    /// Error parsing an external format (MatrixMarket, TOML-lite,
    /// manifest JSON).
    #[error("parse error: {0}")]
    Parse(String),

    /// Invalid configuration value.
    #[error("config error: {0}")]
    Config(String),

    /// The requested artifact is missing from `artifacts/` — run
    /// `make artifacts` first.
    #[error("missing artifact: {0} (run `make artifacts`)")]
    MissingArtifact(String),

    /// An error surfaced by the XLA/PJRT runtime.
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// Unknown CLI command / bad CLI usage.
    #[error("usage error: {0}")]
    Usage(String),

    /// Underlying IO error.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
