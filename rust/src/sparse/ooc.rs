//! Out-of-core CSR backing: matrices larger than RAM, planned and
//! executed **band by band** (ROADMAP item 4).
//!
//! The template is the PB kernel's bounded-pass spill machinery
//! (`spmm/pb_kernel.rs`): there, a byte budget caps the spill arena
//! and the kernel makes as many passes as the budget demands; here, a
//! byte budget caps how much of `A` is resident at once and the
//! executor makes one pass per row band. Two source shapes:
//!
//! * **File-backed** ([`OocCsr::open`]): a MatrixMarket file is
//!   streamed twice through [`MmStream`] — pass 1 counts entries per
//!   row (O(nrows) memory) and plans the bands
//!   ([`crate::sparse::mm_io::plan_row_bands`]); pass 2 happens lazily
//!   *per band* at execute time, re-streaming the file and keeping
//!   only that band's entries. Peak memory is one band (≤ budget,
//!   unless a single row exceeds it) plus the O(nrows) plan.
//! * **In-memory** ([`OocCsr::from_csr`]): bands are row slices of a
//!   resident CSR ([`Csr::slice_rows`]) — the differential-test
//!   configuration, and the cheap path when a corpus matrix happens to
//!   fit.
//!
//! [`OocSpmm`] drives SpMM over the bands: each band runs through a
//! regular [`CsrSpmm`] — the same nnz-balanced [`Schedule`], the same
//! worker pool, the same micro-kernels — into a recycled band-sized
//! `C` buffer that is then copied into place. Because a band's rows
//! are byte-identical slices of the whole matrix's rows and every `C`
//! row is produced by exactly one band with the identical
//! per-row/per-tile accumulation order, the result is **bitwise
//! identical** to whole-matrix [`CsrSpmm`] at every thread count,
//! tile width, and band budget (`tests/prop_ooc.rs` pins this across
//! the generator suite).

use std::io::BufReader;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::sparse::mm_io::{plan_row_bands, MmStream, MmSymmetry};
use crate::sparse::{Coo, Csr};
use crate::spmm::{check_dims, check_schedule, CsrSpmm, DenseMatrix, Impl, Schedule, Spmm};

enum OocSource {
    /// Re-streamable MatrixMarket file (pass-2 source).
    File(PathBuf),
    /// Resident matrix; bands are row slices.
    Mem(Csr),
}

/// A CSR matrix backed out of core: shape, per-row entry counts, and a
/// band plan are resident; row data is materialized one band at a
/// time.
pub struct OocCsr {
    nrows: usize,
    ncols: usize,
    /// Stored entries after symmetric mirroring. Exact for in-memory
    /// sources; for file sources this is the pre-dedup count (an upper
    /// bound when the file stores duplicate coordinates — SuiteSparse
    /// exports do not).
    nnz: usize,
    /// Entry-count prefix per row (`row_ptr` shape), from pass 1.
    row_prefix: Vec<usize>,
    /// Band boundaries over rows (see
    /// [`crate::sparse::mm_io::plan_row_bands`]).
    band_ptr: Vec<usize>,
    budget_bytes: usize,
    source: OocSource,
}

impl OocCsr {
    /// Open a MatrixMarket file out of core: stream it once to count
    /// entries per row and plan row bands under `budget_bytes`. No row
    /// data is retained.
    pub fn open<P: AsRef<Path>>(path: P, budget_bytes: usize) -> Result<OocCsr> {
        let path = path.as_ref().to_path_buf();
        let mut s = MmStream::open(BufReader::new(std::fs::File::open(&path)?))?;
        let h = s.header();
        let mut counts = vec![0usize; h.nrows];
        let mut n = 0usize;
        while let Some((r, c, _)) = s.next_entry()? {
            counts[r] += 1;
            n += 1;
            if h.symmetry == MmSymmetry::Symmetric && r != c {
                counts[c] += 1;
                n += 1;
            }
        }
        let mut row_prefix = Vec::with_capacity(h.nrows + 1);
        row_prefix.push(0usize);
        let mut acc = 0usize;
        for &k in &counts {
            acc += k;
            row_prefix.push(acc);
        }
        let band_ptr = plan_row_bands(&row_prefix, budget_bytes);
        Ok(OocCsr {
            nrows: h.nrows,
            ncols: h.ncols,
            nnz: n,
            row_prefix,
            band_ptr,
            budget_bytes,
            source: OocSource::File(path),
        })
    }

    /// Wrap a resident CSR with a band plan — the configuration the
    /// differential suite runs, since it makes "out of core" purely an
    /// execution-strategy change.
    pub fn from_csr(csr: Csr, budget_bytes: usize) -> OocCsr {
        let band_ptr = plan_row_bands(&csr.row_ptr, budget_bytes);
        OocCsr {
            nrows: csr.nrows,
            ncols: csr.ncols,
            nnz: csr.nnz(),
            row_prefix: csr.row_ptr.clone(),
            band_ptr,
            budget_bytes,
            source: OocSource::Mem(csr),
        }
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }

    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Stored entries (see the field note on duplicates).
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// The band byte budget this plan was built for.
    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Number of row bands in the plan.
    pub fn n_bands(&self) -> usize {
        self.band_ptr.len().saturating_sub(1)
    }

    /// Rows covered by band `k`.
    pub fn band_rows(&self, k: usize) -> Range<usize> {
        self.band_ptr[k]..self.band_ptr[k + 1]
    }

    /// Stored entries in band `k` (pass-1 counts).
    pub fn band_nnz(&self, k: usize) -> usize {
        let r = self.band_rows(k);
        self.row_prefix[r.end] - self.row_prefix[r.start]
    }

    /// Entry-count prefix per row — `row_ptr`-shaped, so it feeds
    /// [`Schedule::nnz_balanced`] directly.
    pub fn row_prefix(&self) -> &[usize] {
        &self.row_prefix
    }

    /// Materialize band `k` as a standalone CSR segment (rows rebased,
    /// full column space). File-backed sources re-stream the file and
    /// keep only this band's entries; the mirror pass replays
    /// [`Coo::symmetrize`]'s ordering (all stored entries first, then
    /// mirrors, each in file order), so duplicate summation is
    /// bitwise-identical to the whole-matrix read.
    pub fn load_band(&self, k: usize) -> Result<Csr> {
        let rows = self.band_rows(k);
        match &self.source {
            OocSource::Mem(csr) => Ok(csr.slice_rows(rows.start, rows.end)),
            OocSource::File(path) => {
                let mut s = MmStream::open(BufReader::new(std::fs::File::open(path)?))?;
                let h = s.header();
                if h.nrows != self.nrows || h.ncols != self.ncols {
                    return Err(Error::InvalidStructure(format!(
                        "{} changed shape under OocCsr: planned {}x{}, found {}x{}",
                        path.display(),
                        self.nrows,
                        self.ncols,
                        h.nrows,
                        h.ncols
                    )));
                }
                let cap = self.band_nnz(k);
                let mut coo = Coo::with_capacity(rows.len(), self.ncols, cap);
                let mut mirrors: Vec<(usize, usize, f64)> = Vec::new();
                while let Some((r, c, v)) = s.next_entry()? {
                    if rows.contains(&r) {
                        coo.push(r - rows.start, c, v);
                    }
                    if h.symmetry == MmSymmetry::Symmetric && r != c && rows.contains(&c) {
                        mirrors.push((c - rows.start, r, v));
                    }
                }
                for (r, c, v) in mirrors {
                    coo.push(r, c, v);
                }
                Ok(Csr::from_coo(coo))
            }
        }
    }
}

/// Band-by-band SpMM over an [`OocCsr`]. Routes as [`Impl::Csr`] —
/// out-of-core is an execution strategy for the CSR kernel, not a
/// storage format — and is bitwise-identical to whole-matrix
/// [`CsrSpmm`] (module docs explain why).
pub struct OocSpmm {
    ooc: OocCsr,
    threads: usize,
    /// Recycled band-`C` buffer — the bounded-pass arena, reused
    /// across bands and executions exactly like the PB kernel's spill
    /// scratch (`Mutex` + `mem::take`, poison-tolerant: a panicking
    /// worker on a previous execution only loses the recycled
    /// allocation, never correctness).
    scratch: Mutex<Vec<f64>>,
}

impl OocSpmm {
    /// Wrap a planned out-of-core matrix; `threads` workers per band.
    pub fn new(ooc: OocCsr, threads: usize) -> OocSpmm {
        OocSpmm { ooc, threads: threads.max(1), scratch: Mutex::new(Vec::new()) }
    }

    /// The underlying out-of-core plan.
    pub fn backing(&self) -> &OocCsr {
        &self.ooc
    }
}

impl Spmm for OocSpmm {
    fn id(&self) -> Impl {
        Impl::Csr
    }
    fn nrows(&self) -> usize {
        self.ooc.nrows
    }
    fn ncols(&self) -> usize {
        self.ooc.ncols
    }
    fn nnz(&self) -> usize {
        self.ooc.nnz
    }

    fn execute(&self, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
        self.execute_with(b, c, &self.plan(None))
    }

    /// The whole-matrix schedule shape: nnz-balanced over the pass-1
    /// row counts. Only its tile width reaches the band executors —
    /// each band re-plans its own partitions over the band's rows, the
    /// whole point of band-local execution.
    fn plan(&self, tile: Option<usize>) -> Schedule {
        Schedule::nnz_balanced(&self.ooc.row_prefix, self.threads).with_tile(tile)
    }

    fn execute_with(&self, b: &DenseMatrix, c: &mut DenseMatrix, s: &Schedule) -> Result<()> {
        check_dims(self.ooc.nrows, self.ooc.ncols, b, c)?;
        check_schedule(self.ooc.nrows, s)?;
        let d = b.ncols;
        let mut cbuf =
            std::mem::take(&mut *self.scratch.lock().unwrap_or_else(|e| e.into_inner()));
        for k in 0..self.ooc.n_bands() {
            let rows = self.ooc.band_rows(k);
            let band = self.ooc.load_band(k)?;
            let kern = CsrSpmm::new(band, self.threads);
            let band_schedule = kern.plan(s.tile);
            cbuf.clear();
            cbuf.resize(rows.len() * d, 0.0);
            let mut c_band = DenseMatrix::from_vec(rows.len(), d, std::mem::take(&mut cbuf));
            kern.execute_with(b, &mut c_band, &band_schedule)?;
            c.data[rows.start * d..rows.end * d].copy_from_slice(&c_band.data);
            cbuf = c_band.data;
        }
        *self.scratch.lock().unwrap_or_else(|e| e.into_inner()) = cbuf;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, Prng};
    use crate::spmm::reference_spmm;

    fn band_counts(ooc: &OocCsr) -> Vec<usize> {
        (0..ooc.n_bands()).map(|k| ooc.band_rows(k).len()).collect()
    }

    #[test]
    fn from_csr_band_plan_covers_all_rows() {
        let a = erdos_renyi(60, 60, 4.0, &mut Prng::new(0x00c1));
        for budget in [0usize, 256, 4096, usize::MAX] {
            let ooc = OocCsr::from_csr(a.clone(), budget);
            assert_eq!(band_counts(&ooc).iter().sum::<usize>(), 60, "budget={budget}");
            let total: usize = (0..ooc.n_bands()).map(|k| ooc.band_nnz(k)).sum();
            assert_eq!(total, a.nnz());
        }
        assert_eq!(OocCsr::from_csr(a.clone(), usize::MAX).n_bands(), 1);
        assert_eq!(OocCsr::from_csr(a, 0).n_bands(), 60);
    }

    #[test]
    fn bands_reassemble_the_matrix() {
        let a = erdos_renyi(50, 50, 3.0, &mut Prng::new(0x00c2));
        let ooc = OocCsr::from_csr(a.clone(), 300);
        assert!(ooc.n_bands() > 1, "budget must force multiple bands");
        for k in 0..ooc.n_bands() {
            let rows = ooc.band_rows(k);
            let band = ooc.load_band(k).unwrap();
            band.validate().unwrap();
            for (i, r) in rows.enumerate() {
                assert_eq!(band.row_cols(i), a.row_cols(r));
                assert_eq!(band.row_vals(i), a.row_vals(r));
            }
        }
    }

    #[test]
    fn ooc_execute_matches_csr_bitwise_mem_source() {
        let mut rng = Prng::new(0x00c3);
        let a = erdos_renyi(120, 120, 5.0, &mut rng);
        let d = 7;
        let b = DenseMatrix::random(120, d, &mut rng);
        let want = reference_spmm(&a, &b);
        let csr = CsrSpmm::new(a.clone(), 2);
        let mut c_csr = DenseMatrix::zeros(120, d);
        csr.execute(&b, &mut c_csr).unwrap();
        for budget in [0usize, 1024, usize::MAX] {
            let ooc = OocSpmm::new(OocCsr::from_csr(a.clone(), budget), 2);
            // stale C: every row must be overwritten by exactly one band
            let mut c = DenseMatrix::from_vec(120, d, vec![9.0; 120 * d]);
            ooc.execute(&b, &mut c).unwrap();
            assert!(c.max_abs_diff(&want) < 1e-12);
            assert_eq!(c.data, c_csr.data, "budget={budget} not bitwise");
        }
    }

    #[test]
    fn dimension_errors_propagate() {
        let a = erdos_renyi(10, 10, 2.0, &mut Prng::new(0x00c4));
        let k = OocSpmm::new(OocCsr::from_csr(a, 128), 1);
        let b = DenseMatrix::zeros(11, 3);
        let mut c = DenseMatrix::zeros(10, 3);
        assert!(k.execute(&b, &mut c).is_err());
        let b = DenseMatrix::zeros(10, 3);
        let foreign = Schedule::uniform(11, 1);
        assert!(k.execute_with(&b, &mut c, &foreign).is_err());
    }

    #[test]
    fn open_missing_file_is_io_error() {
        let p = std::env::temp_dir().join("spmm_roofline_ooc_missing.mtx");
        let _ = std::fs::remove_file(&p);
        assert!(matches!(OocCsr::open(&p, 1024), Err(Error::Io(_))));
    }
}
