//! Matrix reordering — the lever behind the paper's premise that
//! structure decides performance.
//!
//! SuiteSparse matrices arrive in orderings that *create* the banded /
//! blocked structure the paper's classes describe; permuting the same
//! graph destroys or restores it. This module provides:
//!
//! * [`reverse_cuthill_mckee`] — RCM bandwidth reduction (turns
//!   mesh-like graphs into banded matrices),
//! * [`degree_sort`] — hubs-first ordering (concentrates scale-free
//!   mass into a dense corner → block locality),
//! * [`random_permutation`] — structure destruction (any matrix →
//!   "random" class),
//! * [`permute_symmetric`] — apply `P·A·Pᵀ`.
//!
//! The `reorder` ablation (CLI `repro ablate-reorder`) shows the
//! classifier following the permutation and the measured SpMM moving
//! between the class rooflines — evidence that the models track
//! *structure*, not matrix identity.

use crate::gen::Prng;
use crate::sparse::{Coo, Csr};

/// A named reordering strategy — the unit the adaptive router
/// enumerates over (`coordinator::autotune`). Each variant maps to one
/// of this module's permutation builders; [`Reordering::None`] is the
/// identity (keep the ordering the matrix arrived in).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Reordering {
    /// Keep the registered ordering.
    None,
    /// Reverse Cuthill–McKee bandwidth reduction
    /// ([`reverse_cuthill_mckee`]).
    Rcm,
    /// Hubs-first degree sort ([`degree_sort`]).
    DegreeSort,
}

impl Reordering {
    /// Every strategy, identity first (candidate enumeration order).
    pub const ALL: [Reordering; 3] = [Reordering::None, Reordering::Rcm, Reordering::DegreeSort];

    /// The permutation this strategy produces for `a` (`perm[old] =
    /// new`), or `None` for the identity.
    pub fn permutation(&self, a: &Csr) -> Option<Vec<u32>> {
        match self {
            Reordering::None => None,
            Reordering::Rcm => Some(reverse_cuthill_mckee(a)),
            Reordering::DegreeSort => Some(degree_sort(a)),
        }
    }

    /// Apply the strategy: `P·A·Pᵀ` for a real permutation, a plain
    /// clone for the identity.
    pub fn apply(&self, a: &Csr) -> Csr {
        match self.permutation(a) {
            Some(p) => permute_symmetric(a, &p),
            None => a.clone(),
        }
    }
}

impl std::fmt::Display for Reordering {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Reordering::None => "none",
            Reordering::Rcm => "rcm",
            Reordering::DegreeSort => "degree",
        };
        write!(f, "{s}")
    }
}

/// Invert a permutation: `inv[perm[i]] = i`.
pub fn invert_permutation(perm: &[u32]) -> Vec<u32> {
    debug_assert!(is_permutation(perm));
    let mut inv = vec![0u32; perm.len()];
    for (old, &new) in perm.iter().enumerate() {
        inv[new as usize] = old as u32;
    }
    inv
}

/// Apply a symmetric permutation `P·A·Pᵀ`: entry `(r, c)` moves to
/// `(perm[r], perm[c])`. `perm` must be a permutation of `0..n`.
pub fn permute_symmetric(a: &Csr, perm: &[u32]) -> Csr {
    assert_eq!(a.nrows, a.ncols, "symmetric permutation needs a square matrix");
    assert_eq!(perm.len(), a.nrows);
    debug_assert!(is_permutation(perm));
    let mut coo = Coo::with_capacity(a.nrows, a.ncols, a.nnz());
    for r in 0..a.nrows {
        for (c, v) in a.row_cols(r).iter().zip(a.row_vals(r)) {
            coo.push(perm[r] as usize, perm[*c as usize] as usize, *v);
        }
    }
    Csr::from_coo(coo)
}

fn is_permutation(perm: &[u32]) -> bool {
    let mut seen = vec![false; perm.len()];
    for &p in perm {
        if p as usize >= perm.len() || seen[p as usize] {
            return false;
        }
        seen[p as usize] = true;
    }
    true
}

/// Reverse Cuthill–McKee ordering: BFS from a low-degree vertex,
/// neighbours visited by ascending degree, then reverse. Returns
/// `perm` with `perm[old] = new`.
pub fn reverse_cuthill_mckee(a: &Csr) -> Vec<u32> {
    let n = a.nrows;
    let degree: Vec<usize> = (0..n).map(|r| a.row_len(r)).collect();
    let mut visited = vec![false; n];
    let mut order: Vec<u32> = Vec::with_capacity(n);
    let mut queue: std::collections::VecDeque<u32> = std::collections::VecDeque::new();

    // component by component, seeded at the minimum-degree unvisited
    // vertex
    loop {
        let seed = (0..n)
            .filter(|&v| !visited[v])
            .min_by_key(|&v| degree[v]);
        let Some(seed) = seed else { break };
        visited[seed] = true;
        queue.push_back(seed as u32);
        while let Some(v) = queue.pop_front() {
            order.push(v);
            let mut nbrs: Vec<u32> = a
                .row_cols(v as usize)
                .iter()
                .copied()
                .filter(|&c| !visited[c as usize])
                .collect();
            nbrs.sort_by_key(|&c| degree[c as usize]);
            for c in nbrs {
                if !visited[c as usize] {
                    visited[c as usize] = true;
                    queue.push_back(c);
                }
            }
        }
    }
    // reverse: order[i] gets new index n-1-i
    let mut perm = vec![0u32; n];
    for (i, &old) in order.iter().enumerate() {
        perm[old as usize] = (n - 1 - i) as u32;
    }
    perm
}

/// Hubs-first ordering: vertices sorted by descending degree.
pub fn degree_sort(a: &Csr) -> Vec<u32> {
    let n = a.nrows;
    let mut idx: Vec<u32> = (0..n as u32).collect();
    idx.sort_by_key(|&v| std::cmp::Reverse(a.row_len(v as usize)));
    let mut perm = vec![0u32; n];
    for (new, &old) in idx.iter().enumerate() {
        perm[old as usize] = new as u32;
    }
    perm
}

/// A uniformly random permutation (structure destruction).
pub fn random_permutation(n: usize, rng: &mut Prng) -> Vec<u32> {
    let mut perm: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut perm);
    perm
}

/// Matrix bandwidth: `max |r − c|` over stored entries.
pub fn bandwidth(a: &Csr) -> usize {
    let mut bw = 0usize;
    for r in 0..a.nrows {
        for &c in a.row_cols(r) {
            bw = bw.max((r as i64 - c as i64).unsigned_abs() as usize);
        }
    }
    bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{chung_lu, mesh2d, ChungLuParams, MeshKind, Prng};

    #[test]
    fn permutation_preserves_spectrum_proxy() {
        // P·A·Pᵀ preserves nnz, degrees (as a multiset), and symmetry
        let mut rng = Prng::new(230);
        let a = mesh2d(16, MeshKind::Triangular, 0.9, &mut rng);
        let perm = random_permutation(a.nrows, &mut rng);
        let b = permute_symmetric(&a, &perm);
        assert_eq!(a.nnz(), b.nnz());
        let mut da: Vec<usize> = (0..a.nrows).map(|r| a.row_len(r)).collect();
        let mut db: Vec<usize> = (0..b.nrows).map(|r| b.row_len(r)).collect();
        da.sort();
        db.sort();
        assert_eq!(da, db);
    }

    #[test]
    fn rcm_reduces_mesh_bandwidth() {
        let mut rng = Prng::new(231);
        let a = mesh2d(24, MeshKind::Triangular, 0.9, &mut rng);
        // scramble first, then ask RCM to recover locality
        let scrambled = permute_symmetric(&a, &random_permutation(a.nrows, &mut rng));
        let bw_scrambled = bandwidth(&scrambled);
        let recovered = permute_symmetric(&scrambled, &reverse_cuthill_mckee(&scrambled));
        let bw_rcm = bandwidth(&recovered);
        assert!(
            bw_rcm * 3 < bw_scrambled,
            "RCM {bw_rcm} vs scrambled {bw_scrambled}"
        );
    }

    #[test]
    fn degree_sort_puts_hubs_first() {
        let mut rng = Prng::new(232);
        let a = chung_lu(
            ChungLuParams { n: 2000, alpha: 2.2, avg_deg: 10.0, k_min: 2.0 },
            &mut rng,
        );
        let b = permute_symmetric(&a, &degree_sort(&a));
        // first 1% of rows should now hold far more than 1% of nnz
        let n_head = b.nrows / 100;
        let head: usize = (0..n_head).map(|r| b.row_len(r)).sum();
        assert!(head as f64 / b.nnz() as f64 > 0.05);
        // and rows are non-increasing in length
        for r in 1..b.nrows {
            assert!(b.row_len(r) <= b.row_len(r - 1) || r < 2);
        }
    }

    #[test]
    fn rcm_handles_disconnected_graphs() {
        // two components + an isolated vertex
        let mut coo = Coo::new(5, 5);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        coo.push(2, 3, 1.0);
        coo.push(3, 2, 1.0);
        let a = Csr::from_coo(coo);
        let perm = reverse_cuthill_mckee(&a);
        assert!(is_permutation(&perm));
    }

    #[test]
    fn double_permutation_roundtrip() {
        let mut rng = Prng::new(233);
        let a = mesh2d(10, MeshKind::Road, 0.8, &mut rng);
        let perm = random_permutation(a.nrows, &mut rng);
        let inv = invert_permutation(&perm);
        let back = permute_symmetric(&permute_symmetric(&a, &perm), &inv);
        assert_eq!(a.to_dense(), back.to_dense());
    }

    #[test]
    fn reordering_enum_applies_its_permutation() {
        let mut rng = Prng::new(234);
        let a = mesh2d(12, MeshKind::Triangular, 0.9, &mut rng);
        assert_eq!(Reordering::None.apply(&a).to_dense(), a.to_dense());
        assert!(Reordering::None.permutation(&a).is_none());
        for r in [Reordering::Rcm, Reordering::DegreeSort] {
            let p = r.permutation(&a).unwrap();
            assert!(is_permutation(&p), "{r}");
            assert_eq!(r.apply(&a).to_dense(), permute_symmetric(&a, &p).to_dense());
        }
        assert_eq!(Reordering::Rcm.to_string(), "rcm");
    }
}
