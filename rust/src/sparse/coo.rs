//! Coordinate (triplet) format — the construction format every
//! generator emits and every other format converts from.

use crate::error::{Error, Result};

/// A sparse matrix in coordinate form: parallel `(row, col, val)`
/// arrays. Rows/cols are `u32` (the paper's 4-byte index assumption
/// bounds n < 2^32, comfortably above anything we generate).
#[derive(Debug, Clone, Default)]
pub struct Coo {
    pub nrows: usize,
    pub ncols: usize,
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
}

impl Coo {
    /// Empty matrix of the given shape.
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Coo { nrows, ncols, rows: Vec::new(), cols: Vec::new(), vals: Vec::new() }
    }

    /// Empty matrix with reserved capacity.
    pub fn with_capacity(nrows: usize, ncols: usize, cap: usize) -> Self {
        Coo {
            nrows,
            ncols,
            rows: Vec::with_capacity(cap),
            cols: Vec::with_capacity(cap),
            vals: Vec::with_capacity(cap),
        }
    }

    /// Number of stored entries (before dedup this may exceed the
    /// logical nnz).
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Append one entry. Panics in debug builds on out-of-range
    /// indices.
    #[inline]
    pub fn push(&mut self, row: usize, col: usize, val: f64) {
        debug_assert!(row < self.nrows && col < self.ncols);
        self.rows.push(row as u32);
        self.cols.push(col as u32);
        self.vals.push(val);
    }

    /// Validate index ranges and array lengths.
    pub fn validate(&self) -> Result<()> {
        if self.rows.len() != self.cols.len() || self.rows.len() != self.vals.len() {
            return Err(Error::InvalidStructure(format!(
                "coo arrays disagree: rows={} cols={} vals={}",
                self.rows.len(),
                self.cols.len(),
                self.vals.len()
            )));
        }
        for (i, (&r, &c)) in self.rows.iter().zip(&self.cols).enumerate() {
            if r as usize >= self.nrows || c as usize >= self.ncols {
                return Err(Error::InvalidStructure(format!(
                    "entry {i} ({r},{c}) out of {}x{}",
                    self.nrows, self.ncols
                )));
            }
        }
        Ok(())
    }

    /// Sort entries into row-major order and sum duplicates.
    /// Returns the deduplicated matrix.
    ///
    /// Duplicate coordinates are summed in **insertion order**: the
    /// sort key carries the original index as a tiebreak, so equal
    /// coordinates keep their push order. An earlier revision sorted
    /// with no tiebreak (`sort_unstable_by_key` on the coordinate
    /// alone), which let duplicates accumulate in an arbitrary order —
    /// the sums could then differ in the last ulp from
    /// [`Coo::to_dense`] (which adds in insertion order), breaking the
    /// bitwise agreement the SpGEMM construction path and the
    /// differential tests rely on (regression-tested below with
    /// magnitude-skewed duplicates).
    pub fn sorted_dedup(mut self) -> Coo {
        let n = self.nnz();
        let mut perm: Vec<u32> = (0..n as u32).collect();
        let rows = &self.rows;
        let cols = &self.cols;
        perm.sort_unstable_by_key(|&i| {
            (((rows[i as usize] as u64) << 32) | cols[i as usize] as u64, i)
        });
        let mut out = Coo::with_capacity(self.nrows, self.ncols, n);
        for &pi in &perm {
            let i = pi as usize;
            let (r, c, v) = (self.rows[i], self.cols[i], self.vals[i]);
            if let (Some(&lr), Some(&lc)) = (out.rows.last(), out.cols.last()) {
                if lr == r && lc == c {
                    *out.vals.last_mut().unwrap() += v;
                    continue;
                }
            }
            out.rows.push(r);
            out.cols.push(c);
            out.vals.push(v);
        }
        self.rows = out.rows;
        self.cols = out.cols;
        self.vals = out.vals;
        self
    }

    /// Transpose in place (swaps row/col arrays and the shape).
    pub fn transpose(mut self) -> Coo {
        std::mem::swap(&mut self.rows, &mut self.cols);
        std::mem::swap(&mut self.nrows, &mut self.ncols);
        self
    }

    /// Make the pattern symmetric by adding the transpose of every
    /// off-diagonal entry (values mirrored), then deduplicating.
    /// Used by the graph generators, whose adjacency matrices are
    /// symmetric.
    pub fn symmetrize(mut self) -> Coo {
        let n = self.nnz();
        for i in 0..n {
            let (r, c) = (self.rows[i], self.cols[i]);
            if r != c {
                self.rows.push(c);
                self.cols.push(r);
                self.vals.push(self.vals[i]);
            }
        }
        self.sorted_dedup()
    }

    /// Dense row-major rendering (tests / tiny matrices only).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.nrows * self.ncols];
        for i in 0..self.nnz() {
            d[self.rows[i] as usize * self.ncols + self.cols[i] as usize] += self.vals[i];
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_nnz() {
        let mut m = Coo::new(3, 3);
        m.push(0, 1, 2.0);
        m.push(2, 2, -1.0);
        assert_eq!(m.nnz(), 2);
        m.validate().unwrap();
    }

    #[test]
    fn dedup_sums_duplicates() {
        let mut m = Coo::new(2, 2);
        m.push(1, 0, 1.0);
        m.push(0, 0, 2.0);
        m.push(1, 0, 3.0);
        let m = m.sorted_dedup();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.rows, vec![0, 1]);
        assert_eq!(m.cols, vec![0, 0]);
        assert_eq!(m.vals, vec![2.0, 4.0]);
    }

    #[test]
    fn dedup_sums_in_insertion_order() {
        // magnitude-skewed duplicates make the accumulation order
        // observable: in insertion order 1e16, 1.0, −1e16 the 1.0 is
        // absorbed ((1e16 + 1.0) = 1e16 in f64) and the sum is 0.0,
        // while the order 1e16, −1e16, 1.0 yields 1.0 — so summing in
        // anything but insertion order diverges from Coo::to_dense.
        let mut m = Coo::new(2, 2);
        m.push(0, 1, 1e16);
        m.push(0, 1, 1.0);
        m.push(0, 1, -1e16);
        m.push(1, 0, 2.0);
        let dense_oracle = m.to_dense();
        let deduped = m.sorted_dedup();
        assert_eq!(deduped.nnz(), 2);
        // bitwise: the deduplicated sum must equal the insertion-order
        // accumulation to_dense performed
        assert_eq!(deduped.vals[0], dense_oracle[0 * 2 + 1]);
        assert_eq!(deduped.vals[0], 0.0);
        assert_eq!(deduped.to_dense(), dense_oracle);
    }

    #[test]
    fn explicit_zeros_agree_with_dense_oracle() {
        // explicit zeros are stored entries; summing them with real
        // values must match the dense accumulation exactly
        let mut m = Coo::new(3, 3);
        m.push(0, 0, 0.0);
        m.push(0, 0, 3.0);
        m.push(2, 1, 0.0); // a lone explicit zero survives as stored
        let dense_oracle = m.to_dense();
        let d = m.sorted_dedup();
        assert_eq!(d.nnz(), 2);
        assert_eq!(d.vals, vec![3.0, 0.0]);
        assert_eq!(d.to_dense(), dense_oracle);
        // and the CSR construction path inherits the agreement
        let csr = crate::sparse::Csr::from_coo(d);
        csr.validate().unwrap();
        assert_eq!(csr.to_dense(), dense_oracle);
        assert_eq!(csr.nnz(), 2, "explicit zeros stay stored, not dropped");
    }

    #[test]
    fn symmetrize_mirrors() {
        let mut m = Coo::new(3, 3);
        m.push(0, 1, 5.0);
        m.push(1, 1, 7.0);
        let m = m.symmetrize();
        let d = m.to_dense();
        assert_eq!(d[0 * 3 + 1], 5.0);
        assert_eq!(d[1 * 3 + 0], 5.0);
        assert_eq!(d[1 * 3 + 1], 7.0);
    }

    #[test]
    fn validate_rejects_out_of_range() {
        let m = Coo { nrows: 2, ncols: 2, rows: vec![5], cols: vec![0], vals: vec![1.0] };
        assert!(m.validate().is_err());
    }

    #[test]
    fn transpose_swaps() {
        let mut m = Coo::new(2, 3);
        m.push(0, 2, 1.0);
        let t = m.transpose();
        assert_eq!((t.nrows, t.ncols), (3, 2));
        assert_eq!((t.rows[0], t.cols[0]), (2, 0));
    }
}
