//! ELLPACK (padded fixed-width) format.
//!
//! Every row stores exactly `width` (column, value) slots; short rows
//! are padded with `col = row, val = 0.0` (an always-in-range index so
//! gathers stay valid). ELL is the format the JAX/Pallas layers use:
//! its static shape is what XLA AOT compilation and TPU tiling require
//! (see DESIGN.md §Hardware-Adaptation), and the Rust ELL kernel gives
//! a native apples-to-apples comparison point for the XLA artifact.

use crate::error::{Error, Result};
use crate::sparse::Csr;

/// ELL matrix in row-major slot order: slot `k` of row `r` lives at
/// `r * width + k`.
#[derive(Debug, Clone, PartialEq)]
pub struct Ell {
    pub nrows: usize,
    pub ncols: usize,
    /// Slots per row (≥ the longest CSR row it was built from).
    pub width: usize,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f64>,
}

impl Ell {
    /// Convert from CSR using `width = max_row_len` (panics if the
    /// matrix is empty-width; use [`Ell::from_csr_with_width`] to pad
    /// wider).
    pub fn from_csr(csr: &Csr) -> Ell {
        Self::from_csr_with_width(csr, csr.max_row_len().max(1))
    }

    /// Convert from CSR with an explicit width ≥ `max_row_len`.
    pub fn from_csr_with_width(csr: &Csr, width: usize) -> Ell {
        assert!(width >= csr.max_row_len().max(1), "width too small");
        let mut col_idx = vec![0u32; csr.nrows * width];
        let mut vals = vec![0.0f64; csr.nrows * width];
        for r in 0..csr.nrows {
            let cols = csr.row_cols(r);
            let vs = csr.row_vals(r);
            let base = r * width;
            for k in 0..width {
                if k < cols.len() {
                    col_idx[base + k] = cols[k];
                    vals[base + k] = vs[k];
                } else {
                    // pad with a safe in-range column and a zero value
                    col_idx[base + k] = (r % csr.ncols.max(1)) as u32;
                    vals[base + k] = 0.0;
                }
            }
        }
        Ell { nrows: csr.nrows, ncols: csr.ncols, width, col_idx, vals }
    }

    /// Logical nonzeros (excludes padding).
    pub fn nnz(&self) -> usize {
        self.vals.iter().filter(|v| **v != 0.0).count()
    }

    /// Total stored slots including padding.
    pub fn padded_len(&self) -> usize {
        self.nrows * self.width
    }

    /// Padding overhead ratio `padded / nnz` (∞-safe: returns 0 for an
    /// all-zero matrix).
    pub fn padding_ratio(&self) -> f64 {
        let nnz = self.nnz();
        if nnz == 0 {
            0.0
        } else {
            self.padded_len() as f64 / nnz as f64
        }
    }

    /// Structural validation: in-range column indices, consistent array
    /// lengths.
    pub fn validate(&self) -> Result<()> {
        if self.col_idx.len() != self.padded_len() || self.vals.len() != self.padded_len() {
            return Err(Error::InvalidStructure("ell array lengths".into()));
        }
        for (i, &c) in self.col_idx.iter().enumerate() {
            if c as usize >= self.ncols {
                return Err(Error::InvalidStructure(format!("ell slot {i} col {c} OOB")));
            }
        }
        Ok(())
    }

    /// Dense row-major rendering (tests only; sums slots so padded
    /// zeros are harmless).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.nrows * self.ncols];
        for r in 0..self.nrows {
            for k in 0..self.width {
                let i = r * self.width + k;
                d[r * self.ncols + self.col_idx[i] as usize] += self.vals[i];
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        Csr::from_dense(3, 3, &[1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 3.0, 4.0, 0.0])
    }

    #[test]
    fn ell_roundtrip() {
        let csr = sample();
        let ell = Ell::from_csr(&csr);
        ell.validate().unwrap();
        assert_eq!(ell.width, 2);
        assert_eq!(ell.to_dense(), csr.to_dense());
        assert_eq!(ell.nnz(), 4);
    }

    #[test]
    fn explicit_width_pads() {
        let csr = sample();
        let ell = Ell::from_csr_with_width(&csr, 5);
        ell.validate().unwrap();
        assert_eq!(ell.padded_len(), 15);
        assert_eq!(ell.to_dense(), csr.to_dense());
        assert!((ell.padding_ratio() - 15.0 / 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn width_too_small_panics() {
        let csr = sample();
        let _ = Ell::from_csr_with_width(&csr, 1);
    }

    #[test]
    fn empty_matrix_padding_ratio() {
        let csr = Csr::from_dense(2, 2, &[0.0; 4]);
        let ell = Ell::from_csr(&csr);
        assert_eq!(ell.padding_ratio(), 0.0);
        assert_eq!(ell.width, 1);
    }
}
