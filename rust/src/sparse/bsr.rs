//! Block Sparse Row (BSR): dense `bs × bs` blocks, CSR over blocks.
//!
//! The format the paper's related work optimises toward (DDB/ICS'22
//! builds dense blocks for matrix units; the paper's §II-B lists
//! blocking formats as a key layout axis). BSR trades padding (zeros
//! inside partially-filled blocks) for perfectly regular inner loops —
//! on blocked meshes the fill is high and BSR approaches dense-tile
//! throughput; on random matrices the padding tax is ruinous. The
//! `spmm::BsrSpmm` kernel and the A1 ablation quantify both sides, and
//! the Pallas twin (`python/compile/kernels/bsr_spmm.py`) is the MXU
//! mapping DESIGN.md §Hardware-Adaptation describes.

use crate::error::{Error, Result};
use crate::sparse::Csr;

/// BSR matrix: `block_row_ptr[i]..block_row_ptr[i+1]` indexes the
/// nonzero blocks of block-row `i`; block `k` covers columns
/// `block_col[k]*bs ..` and stores a dense row-major `bs × bs` tile at
/// `blocks[k*bs*bs..]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Bsr {
    pub nrows: usize,
    pub ncols: usize,
    /// Block edge length.
    pub block_size: usize,
    pub n_block_rows: usize,
    pub n_block_cols: usize,
    pub block_row_ptr: Vec<usize>,
    pub block_col: Vec<u32>,
    /// Dense tiles, `block_size²` values each.
    pub blocks: Vec<f64>,
}

impl Bsr {
    /// Convert from CSR with edge length `bs` (rows/cols padded up to
    /// a multiple of `bs` logically; padding stays implicit).
    pub fn from_csr(csr: &Csr, bs: usize) -> Bsr {
        assert!(bs >= 1 && bs <= 1024);
        let n_block_rows = csr.nrows.div_ceil(bs).max(1);
        let n_block_cols = csr.ncols.div_ceil(bs).max(1);

        // pass 1: which blocks exist per block row
        let mut block_row_ptr = vec![0usize; n_block_rows + 1];
        let mut block_col: Vec<u32> = Vec::new();
        let mut blocks: Vec<f64> = Vec::new();
        // scratch: block-col -> slot index for the current block row
        let mut slot_of = vec![usize::MAX; n_block_cols];
        for br in 0..n_block_rows {
            let row_lo = br * bs;
            let row_hi = ((br + 1) * bs).min(csr.nrows);
            let start_slot = block_col.len();
            // discover block columns in ascending order: collect then sort
            let mut cols_here: Vec<u32> = Vec::new();
            for r in row_lo..row_hi {
                for &c in csr.row_cols(r) {
                    let bc = c / bs as u32;
                    if slot_of[bc as usize] == usize::MAX {
                        slot_of[bc as usize] = 0; // mark
                        cols_here.push(bc);
                    }
                }
            }
            cols_here.sort_unstable();
            for (k, &bc) in cols_here.iter().enumerate() {
                slot_of[bc as usize] = start_slot + k;
                block_col.push(bc);
            }
            blocks.resize(block_col.len() * bs * bs, 0.0);
            // pass 2 for this block row: scatter values
            for r in row_lo..row_hi {
                let rr = r - row_lo;
                for (&c, &v) in csr.row_cols(r).iter().zip(csr.row_vals(r)) {
                    let bc = (c / bs as u32) as usize;
                    let slot = slot_of[bc];
                    let cc = c as usize % bs;
                    blocks[slot * bs * bs + rr * bs + cc] = v;
                }
            }
            // reset scratch
            for &bc in &cols_here {
                slot_of[bc as usize] = usize::MAX;
            }
            block_row_ptr[br + 1] = block_col.len();
        }
        Bsr {
            nrows: csr.nrows,
            ncols: csr.ncols,
            block_size: bs,
            n_block_rows,
            n_block_cols,
            block_row_ptr,
            block_col,
            blocks,
        }
    }

    /// Stored (possibly zero) values: `n_blocks · bs²`.
    pub fn stored_len(&self) -> usize {
        self.block_col.len() * self.block_size * self.block_size
    }

    /// Count of structurally nonzero values inside the tiles.
    pub fn nnz(&self) -> usize {
        self.blocks.iter().filter(|v| **v != 0.0).count()
    }

    /// Number of nonzero blocks.
    pub fn n_blocks(&self) -> usize {
        self.block_col.len()
    }

    /// Mean fill of a stored tile (1.0 = fully dense tiles).
    pub fn fill_ratio(&self) -> f64 {
        if self.stored_len() == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.stored_len() as f64
        }
    }

    /// Dense tile `k` as a slice.
    #[inline]
    pub fn block(&self, k: usize) -> &[f64] {
        let sq = self.block_size * self.block_size;
        &self.blocks[k * sq..(k + 1) * sq]
    }

    /// Structural validation.
    pub fn validate(&self) -> Result<()> {
        if self.block_row_ptr.len() != self.n_block_rows + 1
            || *self.block_row_ptr.last().unwrap() != self.block_col.len()
            || self.blocks.len() != self.stored_len()
        {
            return Err(Error::InvalidStructure("bsr arrays inconsistent".into()));
        }
        for br in 0..self.n_block_rows {
            let slots = &self.block_col[self.block_row_ptr[br]..self.block_row_ptr[br + 1]];
            for w in slots.windows(2) {
                if w[0] >= w[1] {
                    return Err(Error::InvalidStructure(format!(
                        "bsr block row {br} not ascending"
                    )));
                }
            }
            if let Some(&bc) = slots.last() {
                if bc as usize >= self.n_block_cols {
                    return Err(Error::InvalidStructure("bsr block col OOB".into()));
                }
            }
        }
        Ok(())
    }

    /// Dense row-major rendering (tests only).
    pub fn to_dense(&self) -> Vec<f64> {
        let bs = self.block_size;
        let mut d = vec![0.0; self.nrows * self.ncols];
        for br in 0..self.n_block_rows {
            for k in self.block_row_ptr[br]..self.block_row_ptr[br + 1] {
                let bc = self.block_col[k] as usize;
                let tile = self.block(k);
                for rr in 0..bs {
                    let r = br * bs + rr;
                    if r >= self.nrows {
                        break;
                    }
                    for cc in 0..bs {
                        let c = bc * bs + cc;
                        if c >= self.ncols {
                            break;
                        }
                        let v = tile[rr * bs + cc];
                        if v != 0.0 {
                            d[r * self.ncols + c] = v;
                        }
                    }
                }
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, mesh2d, MeshKind, Prng};

    #[test]
    fn roundtrip_small() {
        let csr = Csr::from_dense(5, 5, &[
            1.0, 2.0, 0.0, 0.0, 0.0, //
            3.0, 4.0, 0.0, 0.0, 5.0, //
            0.0, 0.0, 6.0, 0.0, 0.0, //
            0.0, 0.0, 0.0, 7.0, 0.0, //
            8.0, 0.0, 0.0, 0.0, 9.0,
        ]);
        let bsr = Bsr::from_csr(&csr, 2);
        bsr.validate().unwrap();
        assert_eq!(bsr.to_dense(), csr.to_dense());
        assert_eq!(bsr.nnz(), 9);
        assert_eq!(bsr.n_block_rows, 3);
    }

    #[test]
    fn roundtrip_random_various_bs() {
        let mut rng = Prng::new(210);
        let csr = erdos_renyi(150, 150, 5.0, &mut rng);
        for bs in [1usize, 2, 3, 4, 8, 16] {
            let bsr = Bsr::from_csr(&csr, bs);
            bsr.validate().unwrap();
            assert_eq!(bsr.to_dense(), csr.to_dense(), "bs={bs}");
            assert_eq!(bsr.nnz(), csr.nnz());
        }
    }

    #[test]
    fn mesh_fills_better_than_random() {
        let mut rng = Prng::new(211);
        let mesh = mesh2d(32, MeshKind::Triangular, 0.9, &mut rng);
        let er = erdos_renyi(mesh.nrows, mesh.ncols, mesh.avg_row_len(), &mut rng);
        let f_mesh = Bsr::from_csr(&mesh, 4).fill_ratio();
        let f_er = Bsr::from_csr(&er, 4).fill_ratio();
        assert!(f_mesh > 1.5 * f_er, "mesh {f_mesh} vs er {f_er}");
    }

    #[test]
    fn bs1_is_csr_like() {
        let mut rng = Prng::new(212);
        let csr = erdos_renyi(60, 60, 4.0, &mut rng);
        let bsr = Bsr::from_csr(&csr, 1);
        assert_eq!(bsr.fill_ratio(), 1.0);
        assert_eq!(bsr.n_blocks(), csr.nnz());
    }
}
