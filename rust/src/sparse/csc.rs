//! Compressed Sparse Column. Used for column-driven analyses and as the
//! transpose machinery for CSR.

use crate::error::{Error, Result};
use crate::sparse::Csr;

/// CSC matrix: `col_ptr[c]..col_ptr[c+1]` indexes the (row-sorted)
/// entries of column `c` in `row_idx` / `vals`.
#[derive(Debug, Clone, PartialEq)]
pub struct Csc {
    pub nrows: usize,
    pub ncols: usize,
    pub col_ptr: Vec<usize>,
    pub row_idx: Vec<u32>,
    pub vals: Vec<f64>,
}

impl Csc {
    /// Counting-sort conversion from CSR — O(nnz + n).
    pub fn from_csr(csr: &Csr) -> Csc {
        let mut col_ptr = vec![0usize; csr.ncols + 1];
        for &c in &csr.col_idx {
            col_ptr[c as usize + 1] += 1;
        }
        for c in 0..csr.ncols {
            col_ptr[c + 1] += col_ptr[c];
        }
        let mut next = col_ptr.clone();
        let mut row_idx = vec![0u32; csr.nnz()];
        let mut vals = vec![0.0f64; csr.nnz()];
        for r in 0..csr.nrows {
            for (c, v) in csr.row_cols(r).iter().zip(csr.row_vals(r)) {
                let slot = next[*c as usize];
                row_idx[slot] = r as u32;
                vals[slot] = *v;
                next[*c as usize] += 1;
            }
        }
        Csc { nrows: csr.nrows, ncols: csr.ncols, col_ptr, row_idx, vals }
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Row indices of column `c`.
    #[inline]
    pub fn col_rows(&self, c: usize) -> &[u32] {
        &self.row_idx[self.col_ptr[c]..self.col_ptr[c + 1]]
    }

    /// Values of column `c`.
    #[inline]
    pub fn col_vals(&self, c: usize) -> &[f64] {
        &self.vals[self.col_ptr[c]..self.col_ptr[c + 1]]
    }

    /// Structural validation (mirror of [`Csr::validate`]).
    pub fn validate(&self) -> Result<()> {
        if self.col_ptr.len() != self.ncols + 1
            || self.col_ptr[0] != 0
            || *self.col_ptr.last().unwrap() != self.nnz()
        {
            return Err(Error::InvalidStructure("csc col_ptr malformed".into()));
        }
        for c in 0..self.ncols {
            let rows = self.col_rows(c);
            for w in rows.windows(2) {
                if w[0] >= w[1] {
                    return Err(Error::InvalidStructure(format!(
                        "col {c} rows not strictly ascending"
                    )));
                }
            }
            if let Some(&r) = rows.last() {
                if r as usize >= self.nrows {
                    return Err(Error::InvalidStructure(format!("col {c} row {r} OOB")));
                }
            }
        }
        Ok(())
    }

    /// Dense row-major rendering (tests only).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.nrows * self.ncols];
        for c in 0..self.ncols {
            for (r, v) in self.col_rows(c).iter().zip(self.col_vals(c)) {
                d[*r as usize * self.ncols + c] = *v;
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csr_to_csc_same_dense() {
        let csr = Csr::from_dense(3, 4, &[
            1.0, 0.0, 2.0, 0.0, //
            0.0, 3.0, 0.0, 0.0, //
            4.0, 0.0, 0.0, 5.0,
        ]);
        let csc = Csc::from_csr(&csr);
        csc.validate().unwrap();
        assert_eq!(csc.to_dense(), csr.to_dense());
        assert_eq!(csc.col_rows(0), &[0, 2]);
        assert_eq!(csc.col_vals(3), &[5.0]);
    }

    #[test]
    fn empty_matrix() {
        let csr = Csr::from_dense(2, 2, &[0.0; 4]);
        let csc = Csc::from_csr(&csr);
        csc.validate().unwrap();
        assert_eq!(csc.nnz(), 0);
    }
}
