//! Compressed Sparse Blocks (Buluç, Fineman, Frigo, Gilbert, Leiserson,
//! SPAA'09) — the cache-blocking format at the heart of the paper's
//! blocked-sparsity model.
//!
//! The matrix is partitioned into `t × t` blocks. Nonzeros are stored
//! per block with *block-relative* 16-bit coordinates, so a stored
//! entry costs 8 (value) + 2 + 2 (indices) = 12 bytes — the same `12·nnz`
//! the paper's traffic model charges for reading `A`. Blocks are kept
//! in block-row-major order with a block-row pointer array, which lets
//! SpMM parallelise over block rows without atomics (each block row
//! owns a disjoint slice of `C`).

use crate::error::{Error, Result};
use crate::sparse::Csr;

/// Metadata for one nonzero block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CsbBlock {
    /// Block-column index (block-row is implicit from `blk_row_ptr`).
    pub bcol: u32,
    /// Start of this block's entries in the entry arrays.
    pub start: usize,
    /// One past the end of this block's entries.
    pub end: usize,
}

impl CsbBlock {
    /// Number of nonzeros stored in this block.
    pub fn len(&self) -> usize {
        self.end - self.start
    }
    /// True when the block stores no entries (never produced by the
    /// builder, but part of the public contract).
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// CSB matrix. `blk_row_ptr[i]..blk_row_ptr[i+1]` indexes the nonzero
/// blocks of block-row `i` (ascending block column); each block's
/// entries live in `rel_row/rel_col/vals[start..end]`, sorted by
/// (relative row, relative col).
#[derive(Debug, Clone, PartialEq)]
pub struct Csb {
    pub nrows: usize,
    pub ncols: usize,
    /// Block dimension `t` (power of two, ≤ 65536 so relative indices
    /// fit `u16`).
    pub block_dim: usize,
    /// Number of block rows: `ceil(nrows / t)`.
    pub n_block_rows: usize,
    /// Number of block cols: `ceil(ncols / t)`.
    pub n_block_cols: usize,
    pub blk_row_ptr: Vec<usize>,
    pub blocks: Vec<CsbBlock>,
    pub rel_row: Vec<u16>,
    pub rel_col: Vec<u16>,
    pub vals: Vec<f64>,
}

impl Csb {
    /// Default block dimension used by the paper's CSB runs: we follow
    /// the original CSB heuristic `t ≈ √n` rounded to a power of two,
    /// clamped to `[256, 65536]` — large enough that block metadata is
    /// negligible, small enough that a block's slice of `B` and `C`
    /// fits in L2.
    pub fn default_block_dim(n: usize) -> usize {
        let mut t = (n as f64).sqrt() as usize;
        t = t.next_power_of_two();
        t.clamp(256, 65536)
    }

    /// Build from CSR with the default block size.
    pub fn from_csr(csr: &Csr) -> Csb {
        Self::from_csr_with_block(csr, Self::default_block_dim(csr.nrows.max(csr.ncols)))
    }

    /// Build from CSR with an explicit block dimension (must be a power
    /// of two in `[1, 65536]`).
    pub fn from_csr_with_block(csr: &Csr, block_dim: usize) -> Csb {
        assert!(block_dim.is_power_of_two() && block_dim <= 65536 && block_dim >= 1);
        let t = block_dim;
        let shift = t.trailing_zeros();
        let mask = (t - 1) as u32;
        let n_block_rows = csr.nrows.div_ceil(t).max(1);
        let n_block_cols = csr.ncols.div_ceil(t).max(1);

        // Pass 1: count entries per (block-row, block-col).
        // A dense n_block_rows × n_block_cols counter is fine at the
        // block sizes we use (≤ (n/t)^2 words).
        let mut counts = vec![0usize; n_block_rows * n_block_cols];
        for r in 0..csr.nrows {
            let br = r >> shift;
            for &c in csr.row_cols(r) {
                counts[br * n_block_cols + (c >> shift) as usize] += 1;
            }
        }

        // Prefix-sum the nonzero blocks into block metadata.
        let mut blk_row_ptr = vec![0usize; n_block_rows + 1];
        let mut blocks = Vec::new();
        let mut offset = 0usize;
        // slot[b] = position in entry arrays where block b writes next
        let mut slot = vec![usize::MAX; n_block_rows * n_block_cols];
        for br in 0..n_block_rows {
            for bc in 0..n_block_cols {
                let cnt = counts[br * n_block_cols + bc];
                if cnt > 0 {
                    slot[br * n_block_cols + bc] = offset;
                    blocks.push(CsbBlock { bcol: bc as u32, start: offset, end: offset + cnt });
                    offset += cnt;
                }
            }
            blk_row_ptr[br + 1] = blocks.len();
        }

        // Pass 2: scatter entries. CSR iteration order is (row, col)
        // ascending, which is exactly (rel_row, rel_col) ascending
        // within each block, so blocks come out sorted for free.
        let nnz = csr.nnz();
        let mut rel_row = vec![0u16; nnz];
        let mut rel_col = vec![0u16; nnz];
        let mut vals = vec![0.0f64; nnz];
        for r in 0..csr.nrows {
            let br = r >> shift;
            let rr = (r as u32 & mask) as u16;
            for (&c, &v) in csr.row_cols(r).iter().zip(csr.row_vals(r)) {
                let b = br * n_block_cols + (c >> shift) as usize;
                let s = slot[b];
                rel_row[s] = rr;
                rel_col[s] = (c & mask) as u16;
                vals[s] = v;
                slot[b] = s + 1;
            }
        }

        Csb {
            nrows: csr.nrows,
            ncols: csr.ncols,
            block_dim: t,
            n_block_rows,
            n_block_cols,
            blk_row_ptr,
            blocks,
            rel_row,
            rel_col,
            vals,
        }
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Number of nonzero blocks `N` (the paper's blocked-model
    /// parameter).
    pub fn n_nonzero_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Average nonzeros per nonzero block `D = nnz / N` (paper Table I).
    pub fn avg_block_density(&self) -> f64 {
        if self.blocks.is_empty() {
            0.0
        } else {
            self.nnz() as f64 / self.blocks.len() as f64
        }
    }

    /// Mean number of *distinct occupied columns* per nonzero block —
    /// the empirical counterpart of the paper's `z = t(1 − e^{−D/t})`.
    pub fn measured_z(&self) -> f64 {
        if self.blocks.is_empty() {
            return 0.0;
        }
        let mut total = 0usize;
        let mut seen = vec![false; self.block_dim];
        for b in &self.blocks {
            let mut cnt = 0usize;
            for &c in &self.rel_col[b.start..b.end] {
                if !seen[c as usize] {
                    seen[c as usize] = true;
                    cnt += 1;
                }
            }
            // reset only the touched flags
            for &c in &self.rel_col[b.start..b.end] {
                seen[c as usize] = false;
            }
            total += cnt;
        }
        total as f64 / self.blocks.len() as f64
    }

    /// Blocks of block-row `br`.
    #[inline]
    pub fn block_row(&self, br: usize) -> &[CsbBlock] {
        &self.blocks[self.blk_row_ptr[br]..self.blk_row_ptr[br + 1]]
    }

    /// Structural validation.
    pub fn validate(&self) -> Result<()> {
        if self.blk_row_ptr.len() != self.n_block_rows + 1 {
            return Err(Error::InvalidStructure("csb blk_row_ptr length".into()));
        }
        if *self.blk_row_ptr.last().unwrap() != self.blocks.len() {
            return Err(Error::InvalidStructure("csb blk_row_ptr end".into()));
        }
        let mut expect_start = 0usize;
        for br in 0..self.n_block_rows {
            let mut last_bcol = None;
            for b in self.block_row(br) {
                if b.start != expect_start || b.end < b.start {
                    return Err(Error::InvalidStructure("csb block ranges not contiguous".into()));
                }
                if b.is_empty() {
                    return Err(Error::InvalidStructure("csb stores an empty block".into()));
                }
                expect_start = b.end;
                if let Some(lb) = last_bcol {
                    if b.bcol <= lb {
                        return Err(Error::InvalidStructure(format!(
                            "block row {br}: bcol not ascending"
                        )));
                    }
                }
                last_bcol = Some(b.bcol);
                if b.bcol as usize >= self.n_block_cols {
                    return Err(Error::InvalidStructure("bcol out of range".into()));
                }
                for i in b.start..b.end {
                    let gr = br * self.block_dim + self.rel_row[i] as usize;
                    let gc = b.bcol as usize * self.block_dim + self.rel_col[i] as usize;
                    if gr >= self.nrows || gc >= self.ncols {
                        return Err(Error::InvalidStructure(format!(
                            "entry {i} maps OOB ({gr},{gc})"
                        )));
                    }
                }
            }
        }
        if expect_start != self.nnz() {
            return Err(Error::InvalidStructure("csb entries not fully covered".into()));
        }
        Ok(())
    }

    /// Dense row-major rendering (tests only).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.nrows * self.ncols];
        for br in 0..self.n_block_rows {
            for b in self.block_row(br) {
                for i in b.start..b.end {
                    let r = br * self.block_dim + self.rel_row[i] as usize;
                    let c = b.bcol as usize * self.block_dim + self.rel_col[i] as usize;
                    d[r * self.ncols + c] = self.vals[i];
                }
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, Prng};

    #[test]
    fn csb_roundtrip_small() {
        let csr = Csr::from_dense(5, 5, &[
            1.0, 0.0, 0.0, 2.0, 0.0, //
            0.0, 3.0, 0.0, 0.0, 0.0, //
            0.0, 0.0, 0.0, 0.0, 4.0, //
            5.0, 0.0, 6.0, 0.0, 0.0, //
            0.0, 0.0, 0.0, 0.0, 7.0,
        ]);
        let csb = Csb::from_csr_with_block(&csr, 2);
        csb.validate().unwrap();
        assert_eq!(csb.to_dense(), csr.to_dense());
        assert_eq!(csb.nnz(), 7);
        assert_eq!(csb.n_block_rows, 3);
        assert_eq!(csb.n_block_cols, 3);
    }

    #[test]
    fn csb_roundtrip_random() {
        let mut rng = Prng::new(13);
        let csr = erdos_renyi(200, 200, 5.0, &mut rng);
        for t in [16usize, 64, 256] {
            let csb = Csb::from_csr_with_block(&csr, t);
            csb.validate().unwrap();
            assert_eq!(csb.to_dense(), csr.to_dense(), "t={t}");
            assert_eq!(csb.nnz(), csr.nnz());
        }
    }

    #[test]
    fn default_block_dim_sane() {
        assert_eq!(Csb::default_block_dim(1 << 20), 1024);
        assert!(Csb::default_block_dim(100) >= 256);
        assert!(Csb::default_block_dim(usize::MAX / 2) <= 65536);
    }

    #[test]
    fn block_density_and_z() {
        // identity: every block on the diagonal has D = t entries in t
        // distinct... no — identity has 1 nonzero per row, rel cols all
        // distinct → z = block size? With t=2 and n=4: two diagonal
        // blocks each with 2 entries in 2 distinct columns.
        let csr = Csr::from_dense(4, 4, &[
            1.0, 0.0, 0.0, 0.0, //
            0.0, 1.0, 0.0, 0.0, //
            0.0, 0.0, 1.0, 0.0, //
            0.0, 0.0, 0.0, 1.0,
        ]);
        let csb = Csb::from_csr_with_block(&csr, 2);
        assert_eq!(csb.n_nonzero_blocks(), 2);
        assert!((csb.avg_block_density() - 2.0).abs() < 1e-12);
        assert!((csb.measured_z() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn nonsquare_blocks() {
        let csr = Csr::from_dense(3, 6, &[
            1.0, 0.0, 0.0, 0.0, 0.0, 2.0, //
            0.0, 0.0, 3.0, 0.0, 0.0, 0.0, //
            0.0, 4.0, 0.0, 0.0, 5.0, 0.0,
        ]);
        let csb = Csb::from_csr_with_block(&csr, 4);
        csb.validate().unwrap();
        assert_eq!(csb.to_dense(), csr.to_dense());
        assert_eq!(csb.n_block_rows, 1);
        assert_eq!(csb.n_block_cols, 2);
    }
}
