//! Compressed Sparse Row — the baseline format of the paper's CSR and
//! MKL-analog kernels, and the canonical in-memory representation the
//! engine converts everything else from.

use crate::error::{Error, Result};
use crate::sparse::{Coo, Csc};
use crate::{BYTES_IDX, BYTES_VAL};

/// CSR matrix: `row_ptr[r]..row_ptr[r+1]` indexes the (column-sorted)
/// entries of row `r` in `col_idx` / `vals`.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f64>,
}

impl Csr {
    /// Build from COO (sorts + deduplicates first).
    pub fn from_coo(coo: Coo) -> Csr {
        let coo = coo.sorted_dedup();
        let mut row_ptr = vec![0usize; coo.nrows + 1];
        for &r in &coo.rows {
            row_ptr[r as usize + 1] += 1;
        }
        for i in 0..coo.nrows {
            row_ptr[i + 1] += row_ptr[i];
        }
        Csr {
            nrows: coo.nrows,
            ncols: coo.ncols,
            row_ptr,
            col_idx: coo.cols,
            vals: coo.vals,
        }
    }

    /// Number of stored nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Column indices of row `r`.
    #[inline]
    pub fn row_cols(&self, r: usize) -> &[u32] {
        &self.col_idx[self.row_ptr[r]..self.row_ptr[r + 1]]
    }

    /// Values of row `r`.
    #[inline]
    pub fn row_vals(&self, r: usize) -> &[f64] {
        &self.vals[self.row_ptr[r]..self.row_ptr[r + 1]]
    }

    /// Number of nonzeros in row `r`.
    #[inline]
    pub fn row_len(&self, r: usize) -> usize {
        self.row_ptr[r + 1] - self.row_ptr[r]
    }

    /// Mean nonzeros per row.
    pub fn avg_row_len(&self) -> f64 {
        if self.nrows == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.nrows as f64
        }
    }

    /// Maximum row length (the ELL width).
    pub fn max_row_len(&self) -> usize {
        (0..self.nrows).map(|r| self.row_len(r)).max().unwrap_or(0)
    }

    /// Structural validation: monotone row pointers, in-range and
    /// strictly ascending column indices per row.
    pub fn validate(&self) -> Result<()> {
        if self.row_ptr.len() != self.nrows + 1 {
            return Err(Error::InvalidStructure(format!(
                "row_ptr len {} != nrows+1 {}",
                self.row_ptr.len(),
                self.nrows + 1
            )));
        }
        if self.row_ptr[0] != 0 || *self.row_ptr.last().unwrap() != self.nnz() {
            return Err(Error::InvalidStructure("row_ptr endpoints wrong".into()));
        }
        if self.col_idx.len() != self.vals.len() {
            return Err(Error::InvalidStructure("col_idx/vals length mismatch".into()));
        }
        for r in 0..self.nrows {
            if self.row_ptr[r] > self.row_ptr[r + 1] {
                return Err(Error::InvalidStructure(format!("row_ptr not monotone at {r}")));
            }
            let cols = self.row_cols(r);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    return Err(Error::InvalidStructure(format!(
                        "row {r} columns not strictly ascending"
                    )));
                }
            }
            if let Some(&c) = cols.last() {
                if c as usize >= self.ncols {
                    return Err(Error::InvalidStructure(format!(
                        "row {r} col {c} >= ncols {}",
                        self.ncols
                    )));
                }
            }
        }
        Ok(())
    }

    /// Bytes this structure occupies under the paper's model:
    /// `nnz·8 (vals) + nnz·4 (col idx) + (n+1)·4 (row ptr)` ≈ `12·nnz`.
    pub fn model_bytes(&self) -> usize {
        self.nnz() * (BYTES_VAL + BYTES_IDX) + (self.nrows + 1) * BYTES_IDX
    }

    /// Copy out rows `[r0, r1)` as a standalone CSR segment over the
    /// full column space, rows rebased to local indices. The row data
    /// (`col_idx`/`vals` slices) are byte-for-byte the originals, so
    /// band-by-band consumers ([`crate::sparse::ooc`]) inherit bitwise
    /// agreement with whole-matrix execution for free.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Csr {
        assert!(r0 <= r1 && r1 <= self.nrows, "band [{r0},{r1}) out of {} rows", self.nrows);
        let (lo, hi) = (self.row_ptr[r0], self.row_ptr[r1]);
        Csr {
            nrows: r1 - r0,
            ncols: self.ncols,
            row_ptr: self.row_ptr[r0..=r1].iter().map(|p| p - lo).collect(),
            col_idx: self.col_idx[lo..hi].to_vec(),
            vals: self.vals[lo..hi].to_vec(),
        }
    }

    /// Convert back to COO (row-major ordered).
    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::with_capacity(self.nrows, self.ncols, self.nnz());
        for r in 0..self.nrows {
            for (c, v) in self.row_cols(r).iter().zip(self.row_vals(r)) {
                coo.rows.push(r as u32);
                coo.cols.push(*c);
                coo.vals.push(*v);
            }
        }
        coo
    }

    /// Transpose via CSC view: CSR of Aᵀ has identical arrays to CSC of
    /// A.
    pub fn transpose(&self) -> Csr {
        let csc = Csc::from_csr(self);
        Csr {
            nrows: self.ncols,
            ncols: self.nrows,
            row_ptr: csc.col_ptr,
            col_idx: csc.row_idx,
            vals: csc.vals,
        }
    }

    /// Dense row-major rendering (tests / tiny matrices only).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.nrows * self.ncols];
        for r in 0..self.nrows {
            for (c, v) in self.row_cols(r).iter().zip(self.row_vals(r)) {
                d[r * self.ncols + *c as usize] = *v;
            }
        }
        d
    }

    /// Build a small CSR directly from a dense row-major slice
    /// (tests only).
    pub fn from_dense(nrows: usize, ncols: usize, dense: &[f64]) -> Csr {
        assert_eq!(dense.len(), nrows * ncols);
        let mut coo = Coo::new(nrows, ncols);
        for r in 0..nrows {
            for c in 0..ncols {
                let v = dense[r * ncols + c];
                if v != 0.0 {
                    coo.push(r, c, v);
                }
            }
        }
        Csr::from_coo(coo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [1 0 2]
        // [0 0 0]
        // [3 4 0]
        Csr::from_dense(3, 3, &[1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 3.0, 4.0, 0.0])
    }

    #[test]
    fn from_coo_roundtrip() {
        let m = sample();
        m.validate().unwrap();
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row_ptr, vec![0, 2, 2, 4]);
        assert_eq!(m.row_cols(0), &[0, 2]);
        assert_eq!(m.row_vals(2), &[3.0, 4.0]);
        let d = m.to_dense();
        assert_eq!(d, vec![1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 3.0, 4.0, 0.0]);
    }

    #[test]
    fn slice_rows_rebases_and_preserves_data() {
        let m = sample();
        let band = m.slice_rows(1, 3);
        band.validate().unwrap();
        assert_eq!((band.nrows, band.ncols), (2, 3));
        assert_eq!(band.row_ptr, vec![0, 0, 2]);
        assert_eq!(band.row_cols(1), m.row_cols(2));
        assert_eq!(band.row_vals(1), m.row_vals(2));
        // degenerate bands: empty and whole
        assert_eq!(m.slice_rows(1, 1).nnz(), 0);
        assert_eq!(m.slice_rows(0, 3), m);
    }

    #[test]
    fn coo_csr_coo_identity() {
        let m = sample();
        let m2 = Csr::from_coo(m.to_coo());
        assert_eq!(m, m2);
    }

    #[test]
    fn transpose_correct() {
        let m = sample();
        let t = m.transpose();
        t.validate().unwrap();
        let d = t.to_dense();
        assert_eq!(d, vec![1.0, 0.0, 3.0, 0.0, 0.0, 4.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn model_bytes_is_12nnz_plus_rowptr() {
        let m = sample();
        assert_eq!(m.model_bytes(), 4 * 12 + 4 * 4);
    }

    #[test]
    fn validate_catches_descending_cols() {
        let mut m = sample();
        m.col_idx.swap(0, 1);
        assert!(m.validate().is_err());
    }

    #[test]
    fn row_stats() {
        let m = sample();
        assert_eq!(m.max_row_len(), 2);
        assert!((m.avg_row_len() - 4.0 / 3.0).abs() < 1e-12);
    }
}
