//! MatrixMarket coordinate-format IO.
//!
//! Supports the `%%MatrixMarket matrix coordinate (real|integer|pattern)
//! (general|symmetric)` subset — enough to exchange matrices with
//! SuiteSparse tooling and to persist generated proxy matrices.
//!
//! Two reading paths share one header parser but keep **independent
//! entry loops**, deliberately:
//!
//! * [`read_coo_from`] — the original materialize-then-convert reader,
//!   kept as the golden oracle for the differential suite
//!   (`tests/prop_mm_io.rs`);
//! * [`MmStream`] — a single-pass streaming entry iterator that never
//!   holds more than one line, feeding the exact-`nnz`-preallocating
//!   [`read_csr_streaming`], the chunked [`StreamingCsrBuilder`], and
//!   the out-of-core backing ([`crate::sparse::ooc::OocCsr`]).
//!
//! Every malformed input — bad banner, truncated body, out-of-range or
//! zero-based indices, declared-`nnz` mismatch or overflow, non-finite
//! values — is a typed [`Error::Parse`], never a panic: corpus files
//! arrive from outside the process and the harness must survive them.

use std::io::{BufRead, BufReader, BufWriter, Lines, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::sparse::{Coo, Csr};
use crate::{BYTES_IDX, BYTES_VAL};

/// Value field of a MatrixMarket banner.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MmField {
    Real,
    Integer,
    /// Pattern files store structure only; every entry reads as `1.0`.
    Pattern,
}

/// Symmetry of a MatrixMarket banner.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MmSymmetry {
    General,
    /// Only one triangle is stored; reading mirrors every off-diagonal
    /// entry (see [`Coo::symmetrize`]).
    Symmetric,
}

/// Parsed banner + size line of a MatrixMarket file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MmHeader {
    pub nrows: usize,
    pub ncols: usize,
    /// Declared stored-entry count (pre-symmetrization, pre-dedup).
    pub nnz: usize,
    pub field: MmField,
    pub symmetry: MmSymmetry,
}

impl MmHeader {
    /// Stored entries after symmetric mirroring, before dedup — the
    /// exact preallocation for the streaming CSR path (an upper bound
    /// only when the file stores duplicates or an off-banner diagonal).
    pub fn expanded_nnz(&self) -> usize {
        match self.symmetry {
            MmSymmetry::General => self.nnz,
            // saturating: the header guard below caps nnz ≤ u32::MAX,
            // so 2·nnz cannot overflow usize on any supported target,
            // but stay total anyway
            MmSymmetry::Symmetric => self.nnz.saturating_mul(2),
        }
    }
}

/// Parse the banner and size line off a line iterator, leaving it
/// positioned at the first entry line. Shared by the oracle reader and
/// the streaming path so both report identical header errors.
fn parse_header<B: BufRead>(lines: &mut Lines<B>) -> Result<MmHeader> {
    let header = lines
        .next()
        .ok_or_else(|| Error::Parse("empty MatrixMarket file".into()))??;
    let h: Vec<String> = header.split_whitespace().map(|s| s.to_lowercase()).collect();
    if h.len() < 4 || h[0] != "%%matrixmarket" || h[1] != "matrix" || h[2] != "coordinate" {
        return Err(Error::Parse(format!("unsupported MatrixMarket header: {header}")));
    }
    let field = match h[3].as_str() {
        "real" => MmField::Real,
        "integer" => MmField::Integer,
        "pattern" => MmField::Pattern,
        other => return Err(Error::Parse(format!("unsupported field type: {other}"))),
    };
    let symmetry = match h.get(4).map(|s| s.as_str()).unwrap_or("general") {
        "general" => MmSymmetry::General,
        "symmetric" => MmSymmetry::Symmetric,
        other => return Err(Error::Parse(format!("unsupported symmetry: {other}"))),
    };

    // skip comments, find the size line
    let mut size_line = None;
    for line in lines.by_ref() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some(t.to_string());
        break;
    }
    let size_line = size_line.ok_or_else(|| Error::Parse("missing size line".into()))?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse::<usize>().map_err(|e| Error::Parse(format!("size line: {e}"))))
        .collect::<Result<_>>()?;
    if dims.len() != 3 {
        return Err(Error::Parse(format!("bad size line: {size_line}")));
    }
    let (nrows, ncols, nnz) = (dims[0], dims[1], dims[2]);
    // The crate's storage model is 32-bit indices (Coo/Csr store u32,
    // and Coo::sorted_dedup permutes entries through a u32 index), so
    // dimensions or entry counts beyond u32::MAX cannot be represented
    // — reject at the header instead of truncating downstream. The
    // symmetric bound is on the *expanded* count the mirroring pass
    // produces.
    let lim = u32::MAX as usize;
    if nrows > lim || ncols > lim {
        return Err(Error::Parse(format!(
            "dimensions {nrows}x{ncols} exceed the 32-bit index model"
        )));
    }
    let expanded = if symmetry == MmSymmetry::Symmetric { nnz.saturating_mul(2) } else { nnz };
    if expanded > lim {
        return Err(Error::Parse(format!(
            "declared nnz {nnz} overflows the 32-bit entry budget{}",
            if symmetry == MmSymmetry::Symmetric { " after symmetric mirroring" } else { "" }
        )));
    }
    if symmetry == MmSymmetry::Symmetric && nrows != ncols {
        return Err(Error::Parse(format!(
            "symmetric banner on a non-square {nrows}x{ncols} matrix"
        )));
    }
    Ok(MmHeader { nrows, ncols, nnz, field, symmetry })
}

/// Single-pass streaming reader over a MatrixMarket body: yields stored
/// entries one at a time as 0-indexed `(row, col, value)` triples, in
/// file order, holding only the current line. Symmetric files yield the
/// *stored* triangle; callers mirror (all library consumers do, so
/// read-side semantics match [`read_coo_from`] exactly).
///
/// The declared-count contract is enforced at the tail: exhausting the
/// body with fewer entries than the header declared is an error
/// surfaced by the final [`MmStream::next_entry`] call (or the last
/// iterator item), so truncated files cannot be mistaken for short
/// ones.
pub struct MmStream<B: BufRead> {
    lines: Lines<B>,
    header: MmHeader,
    seen: usize,
    done: bool,
}

impl<B: BufRead> MmStream<B> {
    /// Parse the banner + size line and position the stream at the
    /// first entry.
    pub fn open(r: B) -> Result<MmStream<B>> {
        let mut lines = r.lines();
        let header = parse_header(&mut lines)?;
        Ok(MmStream { lines, header, seen: 0, done: false })
    }

    /// The parsed banner + size line.
    pub fn header(&self) -> MmHeader {
        self.header
    }

    /// Entries yielded so far.
    pub fn entries_read(&self) -> usize {
        self.seen
    }

    /// Pull the next stored entry, or `Ok(None)` at a well-formed end
    /// of body. Errors are terminal: the stream fuses.
    pub fn next_entry(&mut self) -> Result<Option<(usize, usize, f64)>> {
        if self.done {
            return Ok(None);
        }
        let r = self.next_entry_inner();
        if matches!(r, Err(_) | Ok(None)) {
            self.done = true;
        }
        r
    }

    fn next_entry_inner(&mut self) -> Result<Option<(usize, usize, f64)>> {
        let h = self.header;
        for line in self.lines.by_ref() {
            let line = line?;
            let t = line.trim();
            if t.is_empty() || t.starts_with('%') {
                continue;
            }
            if self.seen == h.nnz {
                return Err(Error::Parse(format!(
                    "body continues past the declared nnz {}",
                    h.nnz
                )));
            }
            let mut it = t.split_whitespace();
            let r: usize = it
                .next()
                .ok_or_else(|| Error::Parse("short entry line".into()))?
                .parse()
                .map_err(|e| Error::Parse(format!("row: {e}")))?;
            let c: usize = it
                .next()
                .ok_or_else(|| Error::Parse("short entry line".into()))?
                .parse()
                .map_err(|e| Error::Parse(format!("col: {e}")))?;
            let v: f64 = match h.field {
                MmField::Pattern => 1.0,
                _ => it
                    .next()
                    .ok_or_else(|| Error::Parse("missing value".into()))?
                    .parse()
                    .map_err(|e| Error::Parse(format!("val: {e}")))?,
            };
            if !v.is_finite() {
                return Err(Error::Parse(format!("non-finite value {v} at ({r},{c})")));
            }
            if r == 0 || c == 0 || r > h.nrows || c > h.ncols {
                return Err(Error::Parse(format!("entry ({r},{c}) out of 1-based range")));
            }
            self.seen += 1;
            return Ok(Some((r - 1, c - 1, v)));
        }
        if self.seen != h.nnz {
            return Err(Error::Parse(format!(
                "declared nnz {} but read {} (truncated body)",
                h.nnz, self.seen
            )));
        }
        Ok(None)
    }
}

impl<B: BufRead> Iterator for MmStream<B> {
    type Item = Result<(usize, usize, f64)>;
    fn next(&mut self) -> Option<Self::Item> {
        self.next_entry().transpose()
    }
}

/// Parse a MatrixMarket file into COO.
pub fn read_coo<P: AsRef<Path>>(path: P) -> Result<Coo> {
    let f = std::fs::File::open(path)?;
    read_coo_from(BufReader::new(f))
}

/// Parse MatrixMarket text from any reader — the materializing oracle
/// path: every stored entry is pushed into one [`Coo`] (file order),
/// then symmetric files are mirrored. The streaming paths below are
/// differential-tested against this reader entry for entry.
pub fn read_coo_from<R: BufRead>(r: R) -> Result<Coo> {
    let mut lines = r.lines();
    let h = parse_header(&mut lines)?;
    let mut coo = Coo::with_capacity(h.nrows, h.ncols, h.nnz);
    let mut seen = 0usize;
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let r: usize = it
            .next()
            .ok_or_else(|| Error::Parse("short entry line".into()))?
            .parse()
            .map_err(|e| Error::Parse(format!("row: {e}")))?;
        let c: usize = it
            .next()
            .ok_or_else(|| Error::Parse("short entry line".into()))?
            .parse()
            .map_err(|e| Error::Parse(format!("col: {e}")))?;
        let v: f64 = match h.field {
            MmField::Pattern => 1.0,
            _ => it
                .next()
                .ok_or_else(|| Error::Parse("missing value".into()))?
                .parse()
                .map_err(|e| Error::Parse(format!("val: {e}")))?,
        };
        if !v.is_finite() {
            return Err(Error::Parse(format!("non-finite value {v} at ({r},{c})")));
        }
        if r == 0 || c == 0 || r > h.nrows || c > h.ncols {
            return Err(Error::Parse(format!("entry ({r},{c}) out of 1-based range")));
        }
        coo.push(r - 1, c - 1, v);
        seen += 1;
    }
    if seen != h.nnz {
        return Err(Error::Parse(format!("declared nnz {} but read {seen}", h.nnz)));
    }
    if h.symmetry == MmSymmetry::Symmetric {
        coo = coo.symmetrize();
    }
    Ok(coo)
}

/// Parse a MatrixMarket file straight to CSR through the streaming
/// reader: one pass over the body into exactly
/// [`MmHeader::expanded_nnz`]-preallocated entry arrays (no line
/// buffering, no reallocation), then the shared sort/dedup conversion.
/// Bitwise-identical to `Csr::from_coo(read_coo(path)?)` — the
/// mirroring and duplicate-summation orders match the oracle's.
pub fn read_csr_streaming<P: AsRef<Path>>(path: P) -> Result<Csr> {
    let f = std::fs::File::open(path)?;
    read_csr_streaming_from(BufReader::new(f))
}

/// [`read_csr_streaming`] over any reader.
pub fn read_csr_streaming_from<R: BufRead>(r: R) -> Result<Csr> {
    let mut s = MmStream::open(r)?;
    let h = s.header();
    let mut coo = Coo::with_capacity(h.nrows, h.ncols, h.expanded_nnz());
    while let Some((r, c, v)) = s.next_entry()? {
        coo.push(r, c, v);
    }
    if h.symmetry == MmSymmetry::Symmetric {
        coo = coo.symmetrize();
    }
    Ok(Csr::from_coo(coo))
}

/// In-memory bytes of a CSR row band: value + index per nonzero plus
/// the row-pointer array — the cost [`plan_row_bands`] budgets.
pub fn band_bytes(rows: usize, nnz: usize) -> usize {
    nnz * (BYTES_VAL + BYTES_IDX) + (rows + 1) * std::mem::size_of::<usize>()
}

/// Split `[0, nrows)` into contiguous row bands whose in-memory CSR
/// cost ([`band_bytes`]) stays within `budget_bytes`, given the
/// entry-count prefix sum per row (`row_ptr` shape:
/// `prefix.len() == nrows + 1`). Returns the band boundaries
/// (`band_ptr[k]..band_ptr[k+1]` is band `k`); bands are never empty,
/// so a single row heavier than the budget still gets its own band —
/// the budget bounds the pass, it never splits a row. `budget_bytes ==
/// 0` therefore degenerates to one band per row (the adversarial
/// geometry the OOC property suite leans on).
pub fn plan_row_bands(prefix: &[usize], budget_bytes: usize) -> Vec<usize> {
    assert!(!prefix.is_empty(), "prefix must have len nrows+1");
    let nrows = prefix.len() - 1;
    let mut ptr = vec![0usize];
    let mut start = 0usize;
    for r in 0..nrows {
        let cost = band_bytes(r + 1 - start, prefix[r + 1] - prefix[start]);
        if cost > budget_bytes && r > start {
            ptr.push(r);
            start = r;
        }
    }
    if nrows > 0 {
        ptr.push(nrows);
    }
    ptr
}

/// One row-band CSR segment of a logical `nrows × ncols` matrix:
/// `csr` holds rows `row_start .. row_start + csr.nrows`, rebased to
/// local indices, over the full column space.
#[derive(Debug, Clone)]
pub struct CsrBand {
    pub row_start: usize,
    pub csr: Csr,
}

/// Chunked CSR construction: entries are pushed in any order (with
/// strict `Err`-not-panic range/finiteness checking) and `finish`
/// emits row-band CSR segments whose in-memory cost each stays within
/// the byte budget ([`plan_row_bands`]). The concatenated bands are
/// row-for-row bitwise-identical to one whole-matrix
/// [`Csr::from_coo`]: the builder performs the *same* global
/// sort/dedup, then slices — so duplicate summation order is the
/// oracle's, and a band boundary can never change a value.
///
/// This is the band emitter behind the out-of-core path; the
/// memory-bounded *ingestion* protocol (never holding the whole file)
/// is [`crate::sparse::ooc::OocCsr`], which re-streams the file per
/// band instead of buffering entries here.
pub struct StreamingCsrBuilder {
    pending: Coo,
    budget_bytes: usize,
}

impl StreamingCsrBuilder {
    /// Builder for an `nrows × ncols` matrix with the given band byte
    /// budget.
    pub fn new(nrows: usize, ncols: usize, budget_bytes: usize) -> StreamingCsrBuilder {
        StreamingCsrBuilder { pending: Coo::new(nrows, ncols), budget_bytes }
    }

    /// Builder with entry capacity reserved up front (the streaming
    /// reader knows [`MmHeader::expanded_nnz`] exactly).
    pub fn with_capacity(
        nrows: usize,
        ncols: usize,
        budget_bytes: usize,
        cap: usize,
    ) -> StreamingCsrBuilder {
        StreamingCsrBuilder { pending: Coo::with_capacity(nrows, ncols, cap), budget_bytes }
    }

    /// Append one 0-indexed entry. Out-of-range indices and non-finite
    /// values are typed errors (the corpus path feeds this from
    /// external files; a debug-assert panic is not an acceptable
    /// failure mode).
    pub fn push(&mut self, row: usize, col: usize, val: f64) -> Result<()> {
        if row >= self.pending.nrows || col >= self.pending.ncols {
            return Err(Error::Parse(format!(
                "entry ({row},{col}) out of {}x{}",
                self.pending.nrows, self.pending.ncols
            )));
        }
        if !val.is_finite() {
            return Err(Error::Parse(format!("non-finite value {val} at ({row},{col})")));
        }
        self.pending.push(row, col, val);
        Ok(())
    }

    /// Entries pushed so far (pre-dedup).
    pub fn nnz(&self) -> usize {
        self.pending.nnz()
    }

    /// Sort, dedup, and emit the row-band segments.
    pub fn finish(self) -> Result<Vec<CsrBand>> {
        let budget = self.budget_bytes;
        let csr = Csr::from_coo(self.pending);
        let band_ptr = plan_row_bands(&csr.row_ptr, budget);
        let mut bands = Vec::with_capacity(band_ptr.len().saturating_sub(1));
        for w in band_ptr.windows(2) {
            bands.push(CsrBand { row_start: w[0], csr: csr.slice_rows(w[0], w[1]) });
        }
        Ok(bands)
    }
}

/// Write a CSR matrix as `%%MatrixMarket matrix coordinate real general`.
pub fn write_csr<P: AsRef<Path>>(path: P, m: &Csr) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% generated by spmm-roofline")?;
    writeln!(w, "{} {} {}", m.nrows, m.ncols, m.nnz())?;
    for r in 0..m.nrows {
        for (c, v) in m.row_cols(r).iter().zip(m.row_vals(r)) {
            writeln!(w, "{} {} {:.17e}", r + 1, *c as usize + 1, v)?;
        }
    }
    Ok(())
}

/// Write a symmetric CSR matrix as `%%MatrixMarket matrix coordinate
/// real symmetric`, storing only the lower triangle (diagonal
/// included) — the format SuiteSparse uses for the paper's mesh/graph
/// matrices. Reading it back through [`read_coo`] symmetrises, so
/// write → read round-trips exactly. Errors if `m` is not symmetric.
pub fn write_csr_symmetric<P: AsRef<Path>>(path: P, m: &Csr) -> Result<()> {
    if m.nrows != m.ncols || m.transpose() != *m {
        return Err(Error::InvalidStructure(
            "write_csr_symmetric needs a (numerically) symmetric square matrix".into(),
        ));
    }
    let nnz_lower: usize = (0..m.nrows)
        .map(|r| m.row_cols(r).iter().filter(|&&c| (c as usize) <= r).count())
        .sum();
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate real symmetric")?;
    writeln!(w, "% generated by spmm-roofline")?;
    writeln!(w, "{} {} {}", m.nrows, m.ncols, nnz_lower)?;
    for r in 0..m.nrows {
        for (c, v) in m.row_cols(r).iter().zip(m.row_vals(r)) {
            if (*c as usize) <= r {
                writeln!(w, "{} {} {:.17e}", r + 1, *c as usize + 1, v)?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_through_file() {
        let csr = Csr::from_dense(3, 3, &[1.5, 0.0, 0.0, 0.0, 0.0, -2.0, 0.0, 3.25, 0.0]);
        let dir = std::env::temp_dir().join("spmm_roofline_mmio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.mtx");
        write_csr(&path, &csr).unwrap();
        let coo = read_coo(&path).unwrap();
        let csr2 = Csr::from_coo(coo);
        assert_eq!(csr.to_dense(), csr2.to_dense());
        // the streaming path lands on the identical CSR
        let csr3 = read_csr_streaming(&path).unwrap();
        assert_eq!(csr2.to_dense(), csr3.to_dense());
        assert_eq!(csr2.vals, csr3.vals);
    }

    #[test]
    fn parses_pattern_and_symmetric() {
        let text = "%%MatrixMarket matrix coordinate pattern symmetric\n% c\n3 3 2\n2 1\n3 3\n";
        let coo = read_coo_from(Cursor::new(text)).unwrap();
        let d = Csr::from_coo(coo).to_dense();
        assert_eq!(d, vec![0.0, 1.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 1.0]);
        let sd = read_csr_streaming_from(Cursor::new(text)).unwrap().to_dense();
        assert_eq!(sd, d);
    }

    #[test]
    fn stream_yields_stored_entries_in_file_order() {
        let text = "%%MatrixMarket matrix coordinate real general\n3 4 3\n2 1 5.0\n1 4 -1.0\n3 2 2.5\n";
        let mut s = MmStream::open(Cursor::new(text)).unwrap();
        let h = s.header();
        assert_eq!((h.nrows, h.ncols, h.nnz), (3, 4, 3));
        assert_eq!(h.field, MmField::Real);
        assert_eq!(h.symmetry, MmSymmetry::General);
        assert_eq!(h.expanded_nnz(), 3);
        let got: Vec<_> = (&mut s).collect::<Result<Vec<_>>>().unwrap();
        assert_eq!(got, vec![(1, 0, 5.0), (0, 3, -1.0), (2, 1, 2.5)]);
        assert_eq!(s.entries_read(), 3);
        // fused: further pulls stay None
        assert!(s.next_entry().unwrap().is_none());
    }

    #[test]
    fn rejects_bad_header() {
        let text = "%%MatrixMarket matrix array real general\n1 1\n1.0\n";
        assert!(read_coo_from(Cursor::new(text)).is_err());
        assert!(MmStream::open(Cursor::new(text)).is_err());
    }

    #[test]
    fn rejects_wrong_count() {
        let text = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n";
        assert!(read_coo_from(Cursor::new(text)).is_err());
        assert!(read_csr_streaming_from(Cursor::new(text)).is_err());
    }

    #[test]
    fn rejects_zero_based_entries() {
        let text = "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n";
        assert!(read_coo_from(Cursor::new(text)).is_err());
        assert!(read_csr_streaming_from(Cursor::new(text)).is_err());
    }

    #[test]
    fn rejects_nnz_overflow_and_nonsquare_symmetric() {
        let big = format!(
            "%%MatrixMarket matrix coordinate real general\n10 10 {}\n",
            u32::MAX as u64 + 1
        );
        assert!(matches!(read_coo_from(Cursor::new(big)), Err(Error::Parse(_))));
        // symmetric doubling overflows the 32-bit entry budget
        let half = format!(
            "%%MatrixMarket matrix coordinate real symmetric\n10 10 {}\n",
            u32::MAX / 2 + 1
        );
        assert!(matches!(read_coo_from(Cursor::new(half)), Err(Error::Parse(_))));
        let nonsq = "%%MatrixMarket matrix coordinate real symmetric\n2 3 1\n1 1 1.0\n";
        assert!(matches!(read_coo_from(Cursor::new(nonsq)), Err(Error::Parse(_))));
    }

    #[test]
    fn rejects_non_finite_values() {
        for bad in ["inf", "-inf", "nan"] {
            let text =
                format!("%%MatrixMarket matrix coordinate real general\n2 2 1\n1 2 {bad}\n");
            assert!(matches!(read_coo_from(Cursor::new(text.clone())), Err(Error::Parse(_))));
            assert!(matches!(
                read_csr_streaming_from(Cursor::new(text)),
                Err(Error::Parse(_))
            ));
        }
    }

    #[test]
    fn plan_row_bands_budgets() {
        // 4 rows with 2 entries each
        let prefix = [0usize, 2, 4, 6, 8];
        // unbounded: one band
        assert_eq!(plan_row_bands(&prefix, usize::MAX), vec![0, 4]);
        // zero budget: one band per row
        assert_eq!(plan_row_bands(&prefix, 0), vec![0, 1, 2, 3, 4]);
        // mid budget: rows pair up (2 rows ≈ 2·2·12 + 3·8 = 72 bytes)
        let two_rows = band_bytes(2, 4);
        let p = plan_row_bands(&prefix, two_rows);
        assert_eq!(p, vec![0, 2, 4]);
        // empty matrix: no bands
        assert_eq!(plan_row_bands(&[0], 64), vec![0]);
    }

    #[test]
    fn builder_bands_concatenate_to_from_coo() {
        // duplicates with magnitude skew: summation order must be the
        // oracle's (see Coo::sorted_dedup) even across band splits
        let mut b = StreamingCsrBuilder::new(4, 4, 0);
        let entries: &[(usize, usize, f64)] = &[
            (2, 1, 1e16),
            (0, 0, 2.0),
            (2, 1, 1.0),
            (3, 3, -4.0),
            (2, 1, -1e16),
            (1, 2, 7.0),
        ];
        let mut coo = Coo::new(4, 4);
        for &(r, c, v) in entries {
            b.push(r, c, v).unwrap();
            coo.push(r, c, v);
        }
        let whole = Csr::from_coo(coo);
        let bands = b.finish().unwrap();
        assert_eq!(bands.len(), 4, "zero budget → one band per row");
        for band in &bands {
            let r = band.row_start;
            assert_eq!(band.csr.nrows, 1);
            assert_eq!(band.csr.row_cols(0), whole.row_cols(r));
            assert_eq!(band.csr.row_vals(0), whole.row_vals(r), "row {r} bitwise");
        }
    }

    #[test]
    fn builder_rejects_bad_pushes() {
        let mut b = StreamingCsrBuilder::new(2, 2, usize::MAX);
        assert!(b.push(2, 0, 1.0).is_err());
        assert!(b.push(0, 5, 1.0).is_err());
        assert!(b.push(0, 0, f64::NAN).is_err());
        assert!(b.push(1, 1, 3.0).is_ok());
        assert_eq!(b.nnz(), 1);
    }
}
