//! Sparse matrix substrate: storage formats, conversions, and
//! MatrixMarket IO.
//!
//! All formats store `f64` values and 32-bit column indices, matching
//! the paper's traffic model assumptions (§III: "matrix values are
//! stored in double-precision floating-point format, while indices in
//! the sparse matrix are stored as 32-bit integers").

mod bsr;
mod coo;
mod csb;
mod csc;
mod csr;
mod ell;
pub mod mm_io;
pub mod ooc;
pub mod reorder;

pub use bsr::Bsr;
pub use coo::Coo;
pub use csb::{Csb, CsbBlock};
pub use csc::Csc;
pub use csr::Csr;
pub use ell::Ell;
pub use ooc::{OocCsr, OocSpmm};
pub use reorder::Reordering;

/// The storage formats the engine can route between.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Format {
    Coo,
    Csr,
    Csc,
    Csb,
    Ell,
    Bsr,
}

impl std::fmt::Display for Format {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Format::Coo => "COO",
            Format::Csr => "CSR",
            Format::Csc => "CSC",
            Format::Csb => "CSB",
            Format::Ell => "ELL",
            Format::Bsr => "BSR",
        };
        write!(f, "{s}")
    }
}
