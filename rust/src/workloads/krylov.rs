//! Block power iteration — the FEM/DFT block-Krylov pattern of
//! Table II rows 2–3 (stiffness/Hamiltonian matrix × block of
//! vectors, Gutknecht's block Krylov methods).

use crate::error::Result;
use crate::spmm::{DenseMatrix, Spmm};

/// Convergence record of [`block_power_iteration`].
#[derive(Debug, Clone)]
pub struct KrylovStats {
    /// Iterations executed.
    pub iters: usize,
    /// Rayleigh-quotient estimate of the dominant eigenvalue after the
    /// final iteration.
    pub lambda_max: f64,
    /// `‖X_k − X_{k−1}‖_F / ‖X_k‖_F` at exit.
    pub residual: f64,
}

/// Run `iters` block power iterations `X ← normalize(A·X)` with a
/// d-wide block, returning the final block and convergence stats.
/// (Orthogonalisation is skipped — this drives the SpMM access
/// pattern, not an eigensolver; the Rayleigh estimate is for the
/// dominant direction only.)
pub fn block_power_iteration(
    a: &dyn Spmm,
    x0: &DenseMatrix,
    iters: usize,
) -> Result<(DenseMatrix, KrylovStats)> {
    assert_eq!(a.ncols(), x0.nrows);
    let mut x = x0.clone();
    normalize(&mut x);
    let mut y = DenseMatrix::zeros(a.nrows(), x.ncols);
    let mut lambda = 0.0;
    let mut residual = f64::INFINITY;
    for _ in 0..iters {
        a.execute(&x, &mut y)?;
        // Rayleigh estimate from the first block column: λ ≈ xᵀ(Ax)
        lambda = x
            .data
            .iter()
            .step_by(x.ncols)
            .zip(y.data.iter().step_by(y.ncols))
            .map(|(xi, yi)| xi * yi)
            .sum::<f64>()
            / x.data
                .iter()
                .step_by(x.ncols)
                .map(|xi| xi * xi)
                .sum::<f64>()
                .max(1e-300);
        normalize(&mut y);
        residual = diff_norm(&x, &y);
        std::mem::swap(&mut x, &mut y);
    }
    Ok((x, KrylovStats { iters, lambda_max: lambda, residual }))
}

fn normalize(x: &mut DenseMatrix) {
    let norm = x.frob_norm().max(1e-300);
    for v in x.data.iter_mut() {
        *v /= norm;
    }
}

fn diff_norm(a: &DenseMatrix, b: &DenseMatrix) -> f64 {
    let num: f64 = a
        .data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt();
    num / b.frob_norm().max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{banded, Prng};
    use crate::sparse::Csr;
    use crate::spmm::{build_native, Impl};

    #[test]
    fn recovers_dominant_eigenvalue_of_diagonal() {
        // diag(1, 2, ..., 5): dominant eigenvalue 5
        let mut dense = vec![0.0; 25];
        for i in 0..5 {
            dense[i * 5 + i] = (i + 1) as f64;
        }
        let a = Csr::from_dense(5, 5, &dense);
        let kernel = build_native(Impl::Csr, &a, 1).unwrap();
        let x0 = DenseMatrix::random(5, 1, &mut Prng::new(250));
        let (_, stats) = block_power_iteration(kernel.as_ref(), &x0, 200).unwrap();
        assert!((stats.lambda_max - 5.0).abs() < 1e-6, "λ={}", stats.lambda_max);
        assert!(stats.residual < 1e-6);
    }

    #[test]
    fn banded_system_converges_and_kernels_agree() {
        let mut rng = Prng::new(251);
        let a = banded(400, 4, 0.6, &mut rng);
        let x0 = DenseMatrix::random(400, 4, &mut rng);
        let mut finals = Vec::new();
        for im in [Impl::Csr, Impl::Opt, Impl::Csb] {
            let k = build_native(im, &a, 1).unwrap();
            let (x, stats) = block_power_iteration(k.as_ref(), &x0, 30).unwrap();
            assert!(stats.residual.is_finite());
            finals.push(x);
        }
        for f in &finals[1..] {
            assert!(f.max_abs_diff(&finals[0]) < 1e-8);
        }
    }
}
