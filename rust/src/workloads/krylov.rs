//! Block power iteration — the FEM/DFT block-Krylov pattern of
//! Table II rows 2–3 (stiffness/Hamiltonian matrix × block of
//! vectors, Gutknecht's block Krylov methods).
//!
//! The iteration lives in the shared chain core
//! ([`crate::workloads::power_chain`]); this standalone entry point
//! wraps it with the kernel's base schedule and a private pool, the
//! same code the engine routes with its cached schedule.

use crate::coordinator::BufferPool;
use crate::error::Result;
use crate::spmm::{DenseMatrix, Spmm};
use crate::workloads::chain::power_chain;

/// Convergence record of [`block_power_iteration`].
#[derive(Debug, Clone)]
pub struct KrylovStats {
    /// Iterations executed.
    pub iters: usize,
    /// Rayleigh-quotient estimate of the dominant eigenvalue after the
    /// final iteration.
    pub lambda_max: f64,
    /// `‖X_k − X_{k−1}‖_F / ‖X_k‖_F` at exit.
    pub residual: f64,
}

/// Run `iters` block power iterations `X ← normalize(A·X)` with a
/// d-wide block, returning the final block and convergence stats.
/// (Orthogonalisation is skipped — this drives the SpMM access
/// pattern, not an eigensolver; the Rayleigh estimate is for the
/// dominant direction only.) A height mismatch between `A` and `x0`
/// is an [`crate::error::Error::DimensionMismatch`], not a panic.
pub fn block_power_iteration(
    a: &dyn Spmm,
    x0: &DenseMatrix,
    iters: usize,
) -> Result<(DenseMatrix, KrylovStats)> {
    let sched = a.plan(None);
    let mut pool = BufferPool::new();
    power_chain(a, &sched, x0, iters, &mut pool).map(|(x, stats, _)| (x, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;
    use crate::gen::{banded, Prng};
    use crate::sparse::Csr;
    use crate::spmm::{build_native, Impl};

    #[test]
    fn recovers_dominant_eigenvalue_of_diagonal() {
        // diag(1, 2, ..., 5): dominant eigenvalue 5
        let mut dense = vec![0.0; 25];
        for i in 0..5 {
            dense[i * 5 + i] = (i + 1) as f64;
        }
        let a = Csr::from_dense(5, 5, &dense);
        let kernel = build_native(Impl::Csr, &a, 1).unwrap();
        let x0 = DenseMatrix::random(5, 1, &mut Prng::new(250));
        let (_, stats) = block_power_iteration(kernel.as_ref(), &x0, 200).unwrap();
        assert!((stats.lambda_max - 5.0).abs() < 1e-6, "λ={}", stats.lambda_max);
        assert!(stats.residual < 1e-6);
    }

    #[test]
    fn banded_system_converges_and_kernels_agree() {
        let mut rng = Prng::new(251);
        let a = banded(400, 4, 0.6, &mut rng);
        let x0 = DenseMatrix::random(400, 4, &mut rng);
        let mut finals = Vec::new();
        for im in [Impl::Csr, Impl::Opt, Impl::Csb] {
            let k = build_native(im, &a, 1).unwrap();
            let (x, stats) = block_power_iteration(k.as_ref(), &x0, 30).unwrap();
            assert!(stats.residual.is_finite());
            finals.push(x);
        }
        for f in &finals[1..] {
            assert!(f.max_abs_diff(&finals[0]) < 1e-8);
        }
    }

    #[test]
    fn height_mismatch_is_an_error_not_a_panic() {
        let mut rng = Prng::new(252);
        let a = banded(50, 2, 0.5, &mut rng);
        let kernel = build_native(Impl::Csr, &a, 1).unwrap();
        let x0 = DenseMatrix::random(49, 2, &mut rng);
        assert!(matches!(
            block_power_iteration(kernel.as_ref(), &x0, 3),
            Err(Error::DimensionMismatch(_))
        ));
    }
}
