//! Batched personalized PageRank (Table II / the paper's intro cites
//! "batched PageRank computations" as an SpMM application): `d`
//! personalization vectors advance simultaneously as the dense block
//! of an SpMM against `Aᵀ` (column-stochastic).
//!
//! The operator derivation and the iteration both live in the shared
//! chain core ([`crate::workloads::transition_matrix`] /
//! [`crate::workloads::pagerank_chain`]); this standalone entry point
//! builds the requested kernel over the derived operator and runs the
//! chain with the kernel's base schedule, exactly like the engine's
//! pipeline route does with its cached schedule.

use crate::coordinator::BufferPool;
use crate::error::Result;
use crate::sparse::Csr;
use crate::spmm::{build_native, DenseMatrix, Impl};
use crate::workloads::chain::{pagerank_chain, transition_matrix};

/// Result of [`batched_pagerank`].
#[derive(Debug, Clone)]
pub struct PageRankResult {
    /// `n × d` scores, one column per personalization vector.
    pub scores: DenseMatrix,
    pub iterations: usize,
    /// Max L1 change in the last iteration (convergence measure).
    pub delta: f64,
}

/// Run batched PageRank with damping `alpha` until `tol` or
/// `max_iters`. `seeds[j]` is the personalization vertex of column
/// `j`. The kernel runs over the column-stochastic transition matrix
/// built from `graph` (dangling vertices redistribute uniformly via a
/// rank-one correction). A non-square graph or an out-of-range seed
/// is an [`crate::error::Error::DimensionMismatch`], not a panic.
pub fn batched_pagerank(
    graph: &Csr,
    seeds: &[usize],
    alpha: f64,
    tol: f64,
    max_iters: usize,
    im: Impl,
    threads: usize,
) -> Result<PageRankResult> {
    let (m, dangling) = transition_matrix(graph)?;
    let kernel = build_native(im, &m, threads)?;
    let sched = kernel.plan(None);
    let mut pool = BufferPool::new();
    pagerank_chain(kernel.as_ref(), &sched, &dangling, seeds, alpha, tol, max_iters, &mut pool)
        .map(|(r, _)| r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;
    use crate::gen::{chung_lu, ChungLuParams, Prng};
    use crate::sparse::Coo;

    fn ring(n: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for i in 0..n {
            coo.push(i, (i + 1) % n, 1.0);
        }
        Csr::from_coo(coo)
    }

    #[test]
    fn ring_is_uniform() {
        // symmetric structure ⇒ scores spread toward uniformity away
        // from the seed; total mass stays ≈ 1 per column
        let g = ring(50);
        let r = batched_pagerank(&g, &[0, 25], 0.85, 1e-10, 500, Impl::Csr, 1).unwrap();
        for j in 0..2 {
            let total: f64 = (0..50).map(|i| r.scores.get(i, j)).sum();
            assert!((total - 1.0).abs() < 1e-8, "col {j} mass {total}");
        }
        assert!(r.delta < 1e-10);
    }

    #[test]
    fn seed_scores_highest_with_strong_teleport() {
        let mut rng = Prng::new(260);
        let g = chung_lu(ChungLuParams { n: 300, alpha: 2.3, avg_deg: 8.0, k_min: 2.0 }, &mut rng);
        let r = batched_pagerank(&g, &[7], 0.5, 1e-9, 300, Impl::Opt, 1).unwrap();
        let seed_score = r.scores.get(7, 0);
        let max_other = (0..300)
            .filter(|&i| i != 7)
            .map(|i| r.scores.get(i, 0))
            .fold(0.0, f64::max);
        assert!(seed_score > max_other, "seed {seed_score} vs {max_other}");
    }

    #[test]
    fn kernels_agree() {
        let mut rng = Prng::new(261);
        let g = chung_lu(ChungLuParams { n: 200, alpha: 2.2, avg_deg: 6.0, k_min: 2.0 }, &mut rng);
        let a = batched_pagerank(&g, &[1, 2, 3], 0.85, 1e-9, 100, Impl::Csr, 1).unwrap();
        let b = batched_pagerank(&g, &[1, 2, 3], 0.85, 1e-9, 100, Impl::Csb, 2).unwrap();
        assert!(a.scores.max_abs_diff(&b.scores) < 1e-9);
    }

    #[test]
    fn handles_dangling_vertices() {
        // vertex 2 has no out-edges
        let mut coo = Coo::new(3, 3);
        coo.push(0, 1, 1.0);
        coo.push(1, 2, 1.0);
        let g = Csr::from_coo(coo);
        let r = batched_pagerank(&g, &[0], 0.85, 1e-12, 500, Impl::Csr, 1).unwrap();
        let total: f64 = (0..3).map(|i| r.scores.get(i, 0)).sum();
        assert!((total - 1.0).abs() < 1e-6, "mass {total}");
    }

    #[test]
    fn bad_arguments_are_errors_not_panics() {
        let g = ring(10);
        // empty seed set
        assert!(matches!(
            batched_pagerank(&g, &[], 0.85, 1e-9, 10, Impl::Csr, 1),
            Err(Error::DimensionMismatch(_))
        ));
        // out-of-range seed
        assert!(matches!(
            batched_pagerank(&g, &[10], 0.85, 1e-9, 10, Impl::Csr, 1),
            Err(Error::DimensionMismatch(_))
        ));
    }
}
