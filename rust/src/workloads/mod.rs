//! Application workloads — the Table II rows, as library APIs.
//!
//! The paper motivates SpMM through GNNs, FEM/DFT block solvers, and
//! batched PageRank. Each workload here drives the [`crate::spmm`]
//! kernels (or an engine-routed kernel) through the access pattern the
//! application actually produces, so the examples and benches exercise
//! SpMM the way downstream users would.
//!
//! The multi-op arithmetic lives in [`chain`]: one chain-execution
//! function per workload, parameterized on a prepared kernel, a
//! schedule, and a buffer pool. The standalone functions
//! ([`gcn_forward`], [`batched_pagerank`], [`block_power_iteration`])
//! are thin wrappers over those cores; the engine routes the same
//! cores through its cached schedules and shared pool
//! ([`crate::coordinator::Engine::submit_pipeline`]), which is what
//! keeps both paths bitwise-identical.

mod chain;
mod gnn;
mod krylov;
mod pagerank;

pub use chain::{
    gcn_chain, gcn_random_inputs, pagerank_chain, power_chain, power_random_input,
    transition_matrix, OpSecs,
};
pub use gnn::{gcn_forward, GcnLayer};
pub use krylov::{block_power_iteration, KrylovStats};
pub use pagerank::{batched_pagerank, PageRankResult};
