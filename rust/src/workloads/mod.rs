//! Application workloads — the Table II rows, as library APIs.
//!
//! The paper motivates SpMM through GNNs, FEM/DFT block solvers, and
//! batched PageRank. Each workload here drives the [`crate::spmm`]
//! kernels (or an engine-routed kernel) through the access pattern the
//! application actually produces, so the examples and benches exercise
//! SpMM the way downstream users would.

mod gnn;
mod krylov;
mod pagerank;

pub use gnn::{gcn_forward, GcnLayer};
pub use krylov::{block_power_iteration, KrylovStats};
pub use pagerank::{batched_pagerank, PageRankResult};
