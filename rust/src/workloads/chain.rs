//! Shared chain-execution core for the pipeline workloads.
//!
//! Every multi-op workload (GCN forward, batched PageRank, block
//! power iteration) is one function here, parameterized on a prepared
//! kernel, a [`Schedule`], and a [`BufferPool`] for the inter-op
//! intermediates. The standalone workload functions
//! ([`crate::workloads::gcn_forward`] etc.) call these with the
//! kernel's own base schedule (`kernel.plan(None)`) and a throwaway
//! pool — byte-for-byte the pre-pipeline behaviour, since every
//! native kernel's `execute` is `execute_with(&base)` and the pool
//! hands back zeroed buffers exactly like `DenseMatrix::zeros`. The
//! engine ([`crate::coordinator::Engine::submit_pipeline`]) calls the
//! *same* functions with its registry-cached schedule and shared
//! pool, which is what makes the engine route bitwise-identical to
//! the free functions by construction.
//!
//! Intermediates ping-pong through the pool: each op releases its
//! consumed input and the next acquire recycles it best-fit, so a
//! chain of any depth touches at most two live scratch buffers
//! instead of two fresh `DenseMatrix::zeros` per op.
//!
//! Each chain also reports a per-op wall-time breakdown
//! ([`OpSecs`]) so whole-pipeline GFLOP/s accounting can show where
//! the time went (the old `bench_workloads` bug divided SpMM-only
//! FLOPs by whole-pipeline time).

use std::time::Instant;

use crate::coordinator::BufferPool;
use crate::error::{Error, Result};
use crate::gen::Prng;
use crate::sparse::Csr;
use crate::spmm::{DenseMatrix, Schedule, Spmm};
use crate::workloads::{GcnLayer, KrylovStats, PageRankResult};

/// Accumulated wall-clock seconds of one op kind within a chain run.
#[derive(Debug, Clone)]
pub struct OpSecs {
    /// Stable op label (`"spmm"`, `"dense"`, `"rank_update"`, ...).
    pub op: &'static str,
    pub secs: f64,
}

/// GCN forward pass over a prepared kernel and a fixed schedule:
/// `H ← relu((A·H)·Wₗ)` per layer, intermediates from `pool`.
///
/// Validates the whole width chain up front
/// (`layer[l].d_in == layer[l-1].d_out`, `layer[0].d_in == h0.ncols`,
/// `h0.nrows == A.ncols`) and returns
/// [`Error::DimensionMismatch`] instead of panicking on bad input.
pub fn gcn_chain(
    kernel: &dyn Spmm,
    sched: &Schedule,
    h0: &DenseMatrix,
    layers: &[GcnLayer],
    pool: &mut BufferPool,
) -> Result<(DenseMatrix, Vec<OpSecs>)> {
    if h0.nrows != kernel.ncols() {
        return Err(Error::DimensionMismatch(format!(
            "H0 has {} rows but A is {}x{}",
            h0.nrows,
            kernel.nrows(),
            kernel.ncols()
        )));
    }
    let mut width = h0.ncols;
    for (l, layer) in layers.iter().enumerate() {
        if layer.d_in() != width {
            return Err(Error::DimensionMismatch(format!(
                "layer {l} expects d_in={} but receives width {width}",
                layer.d_in()
            )));
        }
        width = layer.d_out();
    }

    let (mut spmm_secs, mut dense_secs) = (0.0, 0.0);
    let mut h = h0.clone();
    for layer in layers {
        // propagate: P = A·H
        let mut p = pool.acquire(kernel.nrows(), h.ncols);
        let t = Instant::now();
        if let Err(e) = kernel.execute_with(&h, &mut p, sched) {
            pool.release(p);
            pool.release(h);
            return Err(e);
        }
        spmm_secs += t.elapsed().as_secs_f64();
        pool.release(h);
        // transform + relu: H' = relu(P·W)
        let mut out = pool.acquire(p.nrows, layer.d_out());
        let t = Instant::now();
        dense_matmul_relu(&p, &layer.weight, &mut out);
        dense_secs += t.elapsed().as_secs_f64();
        pool.release(p);
        h = out;
    }
    let per_op = vec![
        OpSecs { op: "spmm", secs: spmm_secs },
        OpSecs { op: "dense", secs: dense_secs },
    ];
    Ok((h, per_op))
}

/// `out = relu(p · w)` — small dense GEMM with fused ReLU (d is
/// tall-and-skinny so a simple ikj loop vectorises fine). Shapes are
/// validated by the callers ([`gcn_chain`]).
pub(crate) fn dense_matmul_relu(p: &DenseMatrix, w: &DenseMatrix, out: &mut DenseMatrix) {
    debug_assert_eq!(p.ncols, w.nrows);
    out.fill_zero();
    for r in 0..p.nrows {
        let prow = p.row(r);
        let orow = out.row_mut(r);
        for (k, &pv) in prow.iter().enumerate() {
            let wrow = w.row(k);
            for j in 0..wrow.len() {
                orow[j] += pv * wrow[j];
            }
        }
        for v in orow.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }
}

/// The column-stochastic transition operator of a directed graph, as
/// the pair (CSR over destinations, dangling-vertex mask): `M[r][c] =
/// 1/outdeg(c)` for each edge `c→r` — the transpose of the
/// row-normalized adjacency. Shared by the standalone
/// [`crate::workloads::batched_pagerank`] and the engine's pipeline
/// route so both iterate the *same* operator bytes.
pub fn transition_matrix(graph: &Csr) -> Result<(Csr, Vec<bool>)> {
    if graph.nrows != graph.ncols {
        return Err(Error::DimensionMismatch(format!(
            "PageRank needs a square graph, got {}x{}",
            graph.nrows, graph.ncols
        )));
    }
    let n = graph.nrows;
    let mut norm = graph.clone();
    for r in 0..n {
        let deg = norm.row_len(r) as f64;
        let (start, end) = (norm.row_ptr[r], norm.row_ptr[r + 1]);
        for v in &mut norm.vals[start..end] {
            *v = 1.0 / deg;
        }
    }
    let m = norm.transpose();
    let dangling: Vec<bool> = (0..n).map(|r| graph.row_len(r) == 0).collect();
    Ok((m, dangling))
}

/// Batched PageRank iteration over a prepared transition kernel (from
/// [`transition_matrix`]): `x ← α·(M·x + dangling/n) + (1−α)·e_seed`
/// per column until `tol` or `max_iters`. `x`/`y` ping-pong through
/// `pool`.
pub fn pagerank_chain(
    kernel: &dyn Spmm,
    sched: &Schedule,
    dangling: &[bool],
    seeds: &[usize],
    alpha: f64,
    tol: f64,
    max_iters: usize,
    pool: &mut BufferPool,
) -> Result<(PageRankResult, Vec<OpSecs>)> {
    let n = kernel.nrows();
    if seeds.is_empty() || seeds.iter().any(|&s| s >= n) {
        return Err(Error::DimensionMismatch(format!(
            "need ≥1 personalization seed, all < n={n}, got {seeds:?}"
        )));
    }
    if dangling.len() != n {
        return Err(Error::DimensionMismatch(format!(
            "dangling mask covers {} vertices but M has {n} rows",
            dangling.len()
        )));
    }
    let d = seeds.len();

    let mut x = pool.acquire(n, d);
    for (j, &s) in seeds.iter().enumerate() {
        x.set(s, j, 1.0);
    }
    let mut y = pool.acquire(n, d);
    let (mut spmm_secs, mut update_secs) = (0.0, 0.0);
    let mut delta = f64::INFINITY;
    let mut it = 0;
    while it < max_iters && delta > tol {
        let t = Instant::now();
        if let Err(e) = kernel.execute_with(&x, &mut y, sched) {
            pool.release(y);
            pool.release(x);
            return Err(e);
        }
        spmm_secs += t.elapsed().as_secs_f64();
        let t = Instant::now();
        // dangling mass per column
        let mut dm = vec![0.0f64; d];
        for (r, &is_d) in dangling.iter().enumerate() {
            if is_d {
                for (j, slot) in dm.iter_mut().enumerate() {
                    *slot += x.get(r, j);
                }
            }
        }
        delta = 0.0;
        for r in 0..n {
            for j in 0..d {
                let teleport = if r == seeds[j] { 1.0 - alpha } else { 0.0 };
                let new = alpha * (y.get(r, j) + dm[j] / n as f64) + teleport;
                delta = delta.max((new - x.get(r, j)).abs());
                y.set(r, j, new);
            }
        }
        update_secs += t.elapsed().as_secs_f64();
        std::mem::swap(&mut x, &mut y);
        it += 1;
    }
    pool.release(y);
    let per_op = vec![
        OpSecs { op: "spmm", secs: spmm_secs },
        OpSecs { op: "rank_update", secs: update_secs },
    ];
    Ok((PageRankResult { scores: x, iterations: it, delta }, per_op))
}

/// Block power iteration `X ← normalize(A·X)` over a prepared kernel
/// and fixed schedule, `iters` rounds, scratch from `pool`.
pub fn power_chain(
    kernel: &dyn Spmm,
    sched: &Schedule,
    x0: &DenseMatrix,
    iters: usize,
    pool: &mut BufferPool,
) -> Result<(DenseMatrix, KrylovStats, Vec<OpSecs>)> {
    if kernel.ncols() != x0.nrows {
        return Err(Error::DimensionMismatch(format!(
            "A is {}x{} but X0 has {} rows",
            kernel.nrows(),
            kernel.ncols(),
            x0.nrows
        )));
    }
    let mut x = x0.clone();
    normalize(&mut x);
    let mut y = pool.acquire(kernel.nrows(), x.ncols);
    let (mut spmm_secs, mut vec_secs) = (0.0, 0.0);
    let mut lambda = 0.0;
    let mut residual = f64::INFINITY;
    for _ in 0..iters {
        let t = Instant::now();
        if let Err(e) = kernel.execute_with(&x, &mut y, sched) {
            pool.release(y);
            return Err(e);
        }
        spmm_secs += t.elapsed().as_secs_f64();
        let t = Instant::now();
        // Rayleigh estimate from the first block column: λ ≈ xᵀ(Ax)
        lambda = x
            .data
            .iter()
            .step_by(x.ncols)
            .zip(y.data.iter().step_by(y.ncols))
            .map(|(xi, yi)| xi * yi)
            .sum::<f64>()
            / x.data
                .iter()
                .step_by(x.ncols)
                .map(|xi| xi * xi)
                .sum::<f64>()
                .max(1e-300);
        normalize(&mut y);
        residual = diff_norm(&x, &y);
        vec_secs += t.elapsed().as_secs_f64();
        std::mem::swap(&mut x, &mut y);
    }
    pool.release(y);
    let per_op = vec![
        OpSecs { op: "spmm", secs: spmm_secs },
        OpSecs { op: "normalize", secs: vec_secs },
    ];
    Ok((x, KrylovStats { iters, lambda_max: lambda, residual }, per_op))
}

pub(crate) fn normalize(x: &mut DenseMatrix) {
    let norm = x.frob_norm().max(1e-300);
    for v in x.data.iter_mut() {
        *v /= norm;
    }
}

fn diff_norm(a: &DenseMatrix, b: &DenseMatrix) -> f64 {
    let num: f64 = a
        .data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt();
    num / b.frob_norm().max(1e-300)
}

/// Deterministic GCN pipeline inputs from a job seed: `H0 (n×d0)` and
/// one weight per layer (`dims[i] × dims[i+1]`), all drawn from a
/// single `Prng::new(seed)` in order. The engine and the differential
/// tests both use this, so an engine-routed pipeline and a manual
/// composition see identical bytes.
pub fn gcn_random_inputs(n: usize, dims: &[usize], seed: u64) -> (DenseMatrix, Vec<GcnLayer>) {
    let mut rng = Prng::new(seed);
    let h0 = DenseMatrix::random(n, dims[0], &mut rng);
    let layers = dims
        .windows(2)
        .map(|w| GcnLayer::new(DenseMatrix::random(w[0], w[1], &mut rng)))
        .collect();
    (h0, layers)
}

/// Deterministic power-iteration start block (`n×d`) from a job seed
/// — same sharing contract as [`gcn_random_inputs`].
pub fn power_random_input(n: usize, d: usize, seed: u64) -> DenseMatrix {
    DenseMatrix::random(n, d, &mut Prng::new(seed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{chung_lu, ChungLuParams, Prng};
    use crate::spmm::{build_native, Impl};
    use crate::workloads::{batched_pagerank, block_power_iteration, gcn_forward};

    fn graph(n: usize, seed: u64) -> Csr {
        chung_lu(ChungLuParams { n, alpha: 2.3, avg_deg: 8.0, k_min: 2.0 }, &mut Prng::new(seed))
    }

    #[test]
    fn chains_match_their_free_functions_bitwise() {
        let a = graph(180, 270);
        let kernel = build_native(Impl::Opt, &a, 2).unwrap();
        let sched = kernel.plan(None);
        let mut pool = BufferPool::new();

        let (h0, layers) = gcn_random_inputs(180, &[6, 8, 4], 7);
        let (via_chain, per_op) =
            gcn_chain(kernel.as_ref(), &sched, &h0, &layers, &mut pool).unwrap();
        let via_free = gcn_forward(kernel.as_ref(), &h0, &layers).unwrap();
        assert_eq!(via_chain.data.len(), via_free.data.len());
        for (a, b) in via_chain.data.iter().zip(&via_free.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(per_op.len(), 2);

        let x0 = power_random_input(180, 4, 8);
        let (xc, sc, _) = power_chain(kernel.as_ref(), &sched, &x0, 12, &mut pool).unwrap();
        let (xf, sf) = block_power_iteration(kernel.as_ref(), &x0, 12).unwrap();
        for (a, b) in xc.data.iter().zip(&xf.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(sc.lambda_max.to_bits(), sf.lambda_max.to_bits());
        assert_eq!(sc.residual.to_bits(), sf.residual.to_bits());

        let (m, dangling) = transition_matrix(&a).unwrap();
        let mk = build_native(Impl::Csr, &m, 2).unwrap();
        let msched = mk.plan(None);
        let (rc, _) = pagerank_chain(
            mk.as_ref(),
            &msched,
            &dangling,
            &[3, 11],
            0.85,
            1e-9,
            40,
            &mut pool,
        )
        .unwrap();
        let rf = batched_pagerank(&a, &[3, 11], 0.85, 1e-9, 40, Impl::Csr, 2).unwrap();
        assert_eq!(rc.iterations, rf.iterations);
        for (a, b) in rc.scores.data.iter().zip(&rf.scores.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn intermediates_recycle_through_the_pool() {
        let a = graph(150, 271);
        let kernel = build_native(Impl::Csr, &a, 1).unwrap();
        let sched = kernel.plan(None);
        let mut pool = BufferPool::new();
        let (h0, layers) = gcn_random_inputs(150, &[8, 8, 8, 8], 9);
        gcn_chain(kernel.as_ref(), &sched, &h0, &layers, &mut pool).unwrap();
        // 3 layers × 2 acquires = 6, minus the two cold ones (first P
        // plus the first transform output) — everything later must
        // ping-pong out of the pool
        assert!(pool.hits >= 4, "hits {} misses {}", pool.hits, pool.misses);
        assert!(pool.misses <= 2, "hits {} misses {}", pool.hits, pool.misses);
    }

    #[test]
    fn shape_errors_are_errors_not_panics() {
        let a = graph(60, 272);
        let kernel = build_native(Impl::Csr, &a, 1).unwrap();
        let sched = kernel.plan(None);
        let mut pool = BufferPool::new();
        // mismatched layer chain
        let (h0, _) = gcn_random_inputs(60, &[4], 1);
        let bad = vec![GcnLayer::new(DenseMatrix::zeros(5, 3))];
        assert!(matches!(
            gcn_chain(kernel.as_ref(), &sched, &h0, &bad, &mut pool),
            Err(Error::DimensionMismatch(_))
        ));
        // seed out of range
        let (m, dangling) = transition_matrix(&a).unwrap();
        let mk = build_native(Impl::Csr, &m, 1).unwrap();
        let ms = mk.plan(None);
        assert!(matches!(
            pagerank_chain(mk.as_ref(), &ms, &dangling, &[99], 0.85, 1e-9, 5, &mut pool),
            Err(Error::DimensionMismatch(_))
        ));
        // wrong X0 height
        let x0 = DenseMatrix::zeros(10, 2);
        assert!(matches!(
            power_chain(kernel.as_ref(), &sched, &x0, 3, &mut pool),
            Err(Error::DimensionMismatch(_))
        ));
        // non-square graph for the transition operator
        let rect = Csr::from_dense(2, 3, &[1.0, 0.0, 0.0, 0.0, 1.0, 0.0]);
        assert!(matches!(transition_matrix(&rect), Err(Error::DimensionMismatch(_))));
    }
}
