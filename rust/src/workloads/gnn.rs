//! GCN-style forward pass: `H' = relu((A·H)·W)` per layer (Table II
//! row 1; the paper's introduction leads with GNN training/inference).

use crate::error::Result;
use crate::spmm::{DenseMatrix, Spmm};

/// One GCN layer's parameters: a dense feature transform `W (d_in ×
/// d_out)` applied after propagation.
#[derive(Debug, Clone)]
pub struct GcnLayer {
    pub weight: DenseMatrix,
}

impl GcnLayer {
    pub fn new(weight: DenseMatrix) -> GcnLayer {
        GcnLayer { weight }
    }

    pub fn d_in(&self) -> usize {
        self.weight.nrows
    }
    pub fn d_out(&self) -> usize {
        self.weight.ncols
    }
}

/// Run a multi-layer GCN forward pass over adjacency kernel `a`
/// (already prepared in any format): `H ← relu((A·H)·Wₗ)`.
///
/// Layer widths must chain (`layer[l].d_in == layer[l-1].d_out`,
/// `layer[0].d_in == h0.ncols`). Returns the final features.
pub fn gcn_forward(a: &dyn Spmm, h0: &DenseMatrix, layers: &[GcnLayer]) -> Result<DenseMatrix> {
    let mut h = h0.clone();
    for layer in layers {
        assert_eq!(h.ncols, layer.d_in(), "layer width mismatch");
        // propagate: P = A·H
        let mut p = DenseMatrix::zeros(a.nrows(), h.ncols);
        a.execute(&h, &mut p)?;
        // transform + relu: H' = relu(P·W)
        let mut out = DenseMatrix::zeros(p.nrows, layer.d_out());
        dense_matmul_relu(&p, &layer.weight, &mut out);
        h = out;
    }
    Ok(h)
}

/// `out = relu(p · w)` — small dense GEMM with fused ReLU (d is
/// tall-and-skinny so a simple ikj loop vectorises fine).
fn dense_matmul_relu(p: &DenseMatrix, w: &DenseMatrix, out: &mut DenseMatrix) {
    assert_eq!(p.ncols, w.nrows);
    out.fill_zero();
    for r in 0..p.nrows {
        let prow = p.row(r);
        let orow = out.row_mut(r);
        for (k, &pv) in prow.iter().enumerate() {
            let wrow = w.row(k);
            for j in 0..wrow.len() {
                orow[j] += pv * wrow[j];
            }
        }
        for v in orow.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{chung_lu, ChungLuParams, Prng};
    use crate::spmm::{build_native, reference_spmm, Impl};

    #[test]
    fn forward_matches_manual_composition() {
        let mut rng = Prng::new(240);
        let a = chung_lu(ChungLuParams { n: 200, alpha: 2.3, avg_deg: 8.0, k_min: 2.0 }, &mut rng);
        let h0 = DenseMatrix::random(200, 6, &mut rng);
        let w = DenseMatrix::random(6, 4, &mut rng);
        let kernel = build_native(Impl::Opt, &a, 1).unwrap();
        let out = gcn_forward(kernel.as_ref(), &h0, &[GcnLayer::new(w.clone())]).unwrap();

        // manual: relu((A·H)·W)
        let p = reference_spmm(&a, &h0);
        for r in 0..200 {
            for j in 0..4 {
                let mut acc = 0.0;
                for k in 0..6 {
                    acc += p.get(r, k) * w.get(k, j);
                }
                let want = acc.max(0.0);
                assert!((out.get(r, j) - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn multilayer_chains_widths() {
        let mut rng = Prng::new(241);
        let a = chung_lu(ChungLuParams { n: 100, alpha: 2.4, avg_deg: 6.0, k_min: 2.0 }, &mut rng);
        let h0 = DenseMatrix::random(100, 8, &mut rng);
        let layers = vec![
            GcnLayer::new(DenseMatrix::random(8, 16, &mut rng)),
            GcnLayer::new(DenseMatrix::random(16, 4, &mut rng)),
        ];
        let kernel = build_native(Impl::Csr, &a, 1).unwrap();
        let out = gcn_forward(kernel.as_ref(), &h0, &layers).unwrap();
        assert_eq!((out.nrows, out.ncols), (100, 4));
        assert!(out.data.iter().all(|&x| x >= 0.0), "relu output must be nonneg");
    }

    #[test]
    fn kernels_agree_through_the_workload() {
        let mut rng = Prng::new(242);
        let a = chung_lu(ChungLuParams { n: 150, alpha: 2.2, avg_deg: 7.0, k_min: 2.0 }, &mut rng);
        let h0 = DenseMatrix::random(150, 5, &mut rng);
        let layers = vec![GcnLayer::new(DenseMatrix::random(5, 5, &mut rng))];
        let outs: Vec<DenseMatrix> = Impl::NATIVE
            .iter()
            .map(|&im| {
                let k = build_native(im, &a, 2).unwrap();
                gcn_forward(k.as_ref(), &h0, &layers).unwrap()
            })
            .collect();
        for o in &outs[1..] {
            assert!(o.max_abs_diff(&outs[0]) < 1e-10);
        }
    }
}
