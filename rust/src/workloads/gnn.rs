//! GCN-style forward pass: `H' = relu((A·H)·W)` per layer (Table II
//! row 1; the paper's introduction leads with GNN training/inference).
//!
//! The arithmetic lives in the shared chain core
//! ([`crate::workloads::gcn_chain`]); this standalone entry point
//! wraps it with the kernel's own base schedule and a private buffer
//! pool, so existing callers keep the old one-call API while the
//! engine routes the same code through its cached schedule and shared
//! pool ([`crate::coordinator::Engine::submit_pipeline`]).

use crate::coordinator::BufferPool;
use crate::error::Result;
use crate::spmm::{DenseMatrix, Spmm};
use crate::workloads::chain::gcn_chain;

/// One GCN layer's parameters: a dense feature transform `W (d_in ×
/// d_out)` applied after propagation.
#[derive(Debug, Clone)]
pub struct GcnLayer {
    pub weight: DenseMatrix,
}

impl GcnLayer {
    pub fn new(weight: DenseMatrix) -> GcnLayer {
        GcnLayer { weight }
    }

    pub fn d_in(&self) -> usize {
        self.weight.nrows
    }
    pub fn d_out(&self) -> usize {
        self.weight.ncols
    }
}

/// Run a multi-layer GCN forward pass over adjacency kernel `a`
/// (already prepared in any format): `H ← relu((A·H)·Wₗ)`.
///
/// Layer widths must chain (`layer[l].d_in == layer[l-1].d_out`,
/// `layer[0].d_in == h0.ncols`); a mismatch is an
/// [`crate::error::Error::DimensionMismatch`], not a panic. Returns
/// the final features.
pub fn gcn_forward(a: &dyn Spmm, h0: &DenseMatrix, layers: &[GcnLayer]) -> Result<DenseMatrix> {
    let sched = a.plan(None);
    let mut pool = BufferPool::new();
    gcn_chain(a, &sched, h0, layers, &mut pool).map(|(h, _)| h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;
    use crate::gen::{chung_lu, ChungLuParams, Prng};
    use crate::spmm::{build_native, reference_spmm, Impl};

    #[test]
    fn forward_matches_manual_composition() {
        let mut rng = Prng::new(240);
        let a = chung_lu(ChungLuParams { n: 200, alpha: 2.3, avg_deg: 8.0, k_min: 2.0 }, &mut rng);
        let h0 = DenseMatrix::random(200, 6, &mut rng);
        let w = DenseMatrix::random(6, 4, &mut rng);
        let kernel = build_native(Impl::Opt, &a, 1).unwrap();
        let out = gcn_forward(kernel.as_ref(), &h0, &[GcnLayer::new(w.clone())]).unwrap();

        // manual: relu((A·H)·W)
        let p = reference_spmm(&a, &h0);
        for r in 0..200 {
            for j in 0..4 {
                let mut acc = 0.0;
                for k in 0..6 {
                    acc += p.get(r, k) * w.get(k, j);
                }
                let want = acc.max(0.0);
                assert!((out.get(r, j) - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn multilayer_chains_widths() {
        let mut rng = Prng::new(241);
        let a = chung_lu(ChungLuParams { n: 100, alpha: 2.4, avg_deg: 6.0, k_min: 2.0 }, &mut rng);
        let h0 = DenseMatrix::random(100, 8, &mut rng);
        let layers = vec![
            GcnLayer::new(DenseMatrix::random(8, 16, &mut rng)),
            GcnLayer::new(DenseMatrix::random(16, 4, &mut rng)),
        ];
        let kernel = build_native(Impl::Csr, &a, 1).unwrap();
        let out = gcn_forward(kernel.as_ref(), &h0, &layers).unwrap();
        assert_eq!((out.nrows, out.ncols), (100, 4));
        assert!(out.data.iter().all(|&x| x >= 0.0), "relu output must be nonneg");
    }

    #[test]
    fn kernels_agree_through_the_workload() {
        let mut rng = Prng::new(242);
        let a = chung_lu(ChungLuParams { n: 150, alpha: 2.2, avg_deg: 7.0, k_min: 2.0 }, &mut rng);
        let h0 = DenseMatrix::random(150, 5, &mut rng);
        let layers = vec![GcnLayer::new(DenseMatrix::random(5, 5, &mut rng))];
        let outs: Vec<DenseMatrix> = Impl::NATIVE
            .iter()
            .map(|&im| {
                let k = build_native(im, &a, 2).unwrap();
                gcn_forward(k.as_ref(), &h0, &layers).unwrap()
            })
            .collect();
        for o in &outs[1..] {
            assert!(o.max_abs_diff(&outs[0]) < 1e-10);
        }
    }

    #[test]
    fn width_mismatch_is_an_error_not_a_panic() {
        let mut rng = Prng::new(243);
        let a = chung_lu(ChungLuParams { n: 80, alpha: 2.3, avg_deg: 6.0, k_min: 2.0 }, &mut rng);
        let h0 = DenseMatrix::random(80, 6, &mut rng);
        let layers = vec![GcnLayer::new(DenseMatrix::random(7, 4, &mut rng))];
        let kernel = build_native(Impl::Csr, &a, 1).unwrap();
        assert!(matches!(
            gcn_forward(kernel.as_ref(), &h0, &layers),
            Err(Error::DimensionMismatch(_))
        ));
    }
}
