//! Bench: the concurrent serving front-end — N client threads drive a
//! tenant-scoped SpMM/SpGEMM mix through the bounded queue while the
//! serving loop drains, coalesces same-matrix jobs into pooled-buffer
//! engine batches, and answers every ticket.
//!
//! Reports jobs/sec, the coalesce rate (fraction of jobs that rode a
//! merged batch — the front-end's whole reason to exist), peak queue
//! depth, and admission rejects, then writes the flat record into
//! `BENCH_serve.json` (CI greps it for `"coalesce_rate"`).
//!
//! `REPRO_SCALE` (default 0.25), `REPRO_ITERS` (default 2), and
//! `REPRO_CLIENTS` (default 4) tune load; `REPRO_FAST=1` injects
//! nominal machine parameters to skip STREAM/FMA calibration.

use std::sync::atomic::{AtomicUsize, Ordering};

use spmm_roofline::coordinator::{
    Engine, EngineConfig, JobSpec, ServeConfig, ServeRequest, Server, SpGemmSpec, Submit,
};
use spmm_roofline::gen::representative_suite;
use spmm_roofline::model::MachineParams;
use spmm_roofline::report::atomic_write;
use spmm_roofline::spmm::Impl;

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let scale = envf("REPRO_SCALE", 0.25);
    let iters = envf("REPRO_ITERS", 2.0) as usize;
    let clients = (envf("REPRO_CLIENTS", 4.0) as usize).max(1);
    let fast = std::env::var("REPRO_FAST").map(|v| v == "1").unwrap_or(false);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    let machine = if fast {
        Some(MachineParams { beta_gbs: 25.0, pi_gflops: 100.0 })
    } else {
        None // calibrate via STREAM + FMA loop
    };
    let mut engine = Engine::new(EngineConfig {
        threads,
        machine,
        iters,
        warmup: 1,
        impls: vec![Impl::Csr, Impl::Opt, Impl::Csb],
        artifacts_dir: None,
        ..EngineConfig::default()
    })
    .expect("engine construction");
    println!(
        "serve bench: β={:.1} GB/s π={:.0} GFLOP/s, {} engine threads, {} clients",
        engine.machine().beta_gbs,
        engine.machine().pi_gflops,
        threads,
        clients
    );

    // two tenants sharing the suite: clients of different tenants hit
    // the same *local* names, so coalescing must respect the scoping
    let tenants = ["acme", "beta"];
    let mut names: Vec<String> = Vec::new();
    for proxy in representative_suite() {
        let m = proxy.generate(scale);
        println!(
            "registered {} ({} rows, {} nnz) × {} tenants",
            proxy.name,
            m.nrows,
            m.nnz(),
            tenants.len()
        );
        for t in tenants {
            engine.register_for(t, proxy.name, m.clone()).expect("register");
        }
        names.push(proxy.name.to_string());
    }

    // a small queue relative to the offered load, so backpressure and
    // peak-depth numbers are non-trivial
    let mut server = Server::new(
        engine,
        ServeConfig { queue_capacity: 16, max_drain: 8, ..ServeConfig::default() },
    );
    let handle = server.handle();
    let remaining = AtomicUsize::new(clients);
    let delivered = AtomicUsize::new(0);
    let retries = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for c in 0..clients {
            let h = handle.clone();
            let remaining = &remaining;
            let delivered = &delivered;
            let retries = &retries;
            let names = &names;
            s.spawn(move || {
                let tenant = tenants[c % tenants.len()];
                let mut tickets = Vec::new();
                let mut tag = (c as u64) << 32;
                let mut enqueue = |req: ServeRequest, tickets: &mut Vec<_>| loop {
                    match h.submit(req.clone()) {
                        Ok(Submit::Accepted(t)) => {
                            tickets.push(t);
                            break;
                        }
                        Ok(Submit::Rejected { .. }) => {
                            retries.fetch_add(1, Ordering::Relaxed);
                            std::thread::yield_now();
                        }
                        Err(_) => break, // queue closed underneath us
                    }
                };
                for (i, name) in names.iter().enumerate() {
                    for d in [4usize, 16] {
                        let req = ServeRequest::spmm(tenant, JobSpec::new(name.clone(), d), tag)
                            .with_tag(tag);
                        tag += 1;
                        enqueue(req, &mut tickets);
                    }
                    if i == 0 {
                        let req = ServeRequest::spgemm(
                            tenant,
                            SpGemmSpec::new(name.clone(), name.clone()),
                        )
                        .with_tag(tag);
                        tag += 1;
                        enqueue(req, &mut tickets);
                    }
                }
                if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                    h.close();
                }
                for t in tickets {
                    if t.wait().is_ok() {
                        delivered.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
        server.run();
    });

    let stats = server.stats().clone();
    println!("\n— serving run —");
    println!(
        "  {} jobs done ({} failed), {} delivered to clients, {} serving cycles",
        stats.jobs_done,
        stats.jobs_failed,
        delivered.load(Ordering::Relaxed),
        stats.batches
    );
    println!(
        "  coalesced {} of {} jobs → coalesce rate {:.2}",
        stats.coalesced_jobs,
        stats.jobs_done,
        stats.coalesce_rate()
    );
    println!(
        "  queue: peak depth {}, {} rejects ({} client retries), {:.1} jobs/sec over {:.2}s",
        stats.max_queue_depth,
        stats.rejected,
        retries.load(Ordering::Relaxed),
        stats.jobs_per_sec(),
        stats.wall_secs
    );
    assert!(stats.jobs_done > 0, "serving loop must complete jobs");
    assert_eq!(
        stats.jobs_done,
        delivered.load(Ordering::Relaxed),
        "every done job reaches its ticket"
    );
    if clients >= 2 {
        // with ≥2 clients per tenant-pair hammering the same names,
        // the drain slices must find same-matrix pairs to merge
        assert!(stats.coalesced_jobs > 0, "expected some coalescing under concurrent load");
    }

    atomic_write("BENCH_serve.json", &stats.to_json("bench_serve", clients))
        .expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json");
}
