//! Bench: the STREAM port + peak-FLOP loop that calibrate the
//! roofline's β and π (the paper's §IV-B measured β = 122.6 GB/s on
//! one EPYC-7763 socket).

use spmm_roofline::membench::{peak_flops_gflops, stream_benchmark};

fn main() {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    for len in [1usize << 20, 4 << 20, 16 << 20] {
        let r = stream_benchmark(len, threads, 3);
        println!(
            "STREAM len={:>9} ({:>5.1} MiB/array): copy={:>7.2} scale={:>7.2} add={:>7.2} triad={:>7.2} GB/s",
            len,
            len as f64 * 8.0 / (1 << 20) as f64,
            r.copy_gbs,
            r.scale_gbs,
            r.add_gbs,
            r.triad_gbs
        );
    }
    let pi = peak_flops_gflops(threads);
    println!("peak FMA throughput: {pi:.2} GFLOP/s ({threads} threads)");
    println!("paper reference: β=122.6 GB/s, π≈2509 GFLOP/s (64 cores)");
}
