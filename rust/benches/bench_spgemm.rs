//! Bench: the SpGEMM workload through the adaptive router.
//!
//! SpGEMM is the crate's second workload: for each registered matrix
//! the router multiplies the self-product `A·A` (the classic SpGEMM
//! benchmark — squaring a graph's adjacency matrix), measuring **both**
//! candidate kernels (hash accumulator vs PB merge) and pinning the
//! winner with the pair's measured compression factor
//! `cf = flops / nnz(C)`. The structural contrast mirrors `bench_pb`:
//! the hash kernel's gathers collapse on random structure, the PB
//! merge streams on every structure.
//!
//! Artifact: one `BENCH_route.json` record per measured candidate per
//! pair (bench = `bench_spgemm`, `d = dt = 0` marks the sparse
//! operand), so the SpGEMM predicted-vs-measured line is tracked
//! across PRs whichever kernel wins; the bench asserts the merge
//! preserved every other bench's records (the CI smoke gate).
//!
//! `REPRO_SCALE` (default 0.25) and `REPRO_ITERS` (default 3) tune
//! runtime; `REPRO_FAST=1` injects nominal machine parameters instead
//! of running STREAM (CI smoke mode).

use spmm_roofline::coordinator::{AutotunePolicy, Engine, EngineConfig, SpGemmSpec};
use spmm_roofline::gen::{banded, erdos_renyi, mesh2d, rmat, MeshKind, Prng};
use spmm_roofline::model::MachineParams;
use spmm_roofline::report::{PerfLog, PerfRecord};
use spmm_roofline::sparse::Reordering;
use spmm_roofline::spmm::Impl;

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env1(key: &str) -> bool {
    std::env::var(key).map(|v| v == "1").unwrap_or(false)
}

fn main() {
    let scale = envf("REPRO_SCALE", 0.25);
    let iters = envf("REPRO_ITERS", 3.0) as usize;
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let machine = if env1("REPRO_FAST") {
        Some(MachineParams { beta_gbs: 25.0, pi_gflops: 100.0 })
    } else {
        None
    };
    let mut engine = Engine::new(EngineConfig {
        threads,
        machine,
        iters,
        warmup: 1,
        impls: vec![Impl::Csr], // SpMM kernels are not the subject here
        artifacts_dir: None,
        autotune: AutotunePolicy {
            enabled: true,
            top_k: 16, // measure every SpGEMM candidate
            reorderings: vec![Reordering::None],
            explore_iters: iters.max(1),
            explore_min_secs: 0.02,
        },
    })
    .expect("engine construction");
    println!(
        "SpGEMM bench: β={:.1} GB/s π={:.0} GFLOP/s, {} threads, scale={scale}",
        engine.machine().beta_gbs,
        engine.machine().pi_gflops,
        threads
    );

    let mut rng = Prng::new(0xa9a9);
    let scaled = |base: usize| ((base as f64 * scale) as usize).max(256);
    let er = erdos_renyi(scaled(1 << 16), scaled(1 << 16), 8.0, &mut rng);
    println!("registered er_gemm ({} rows, {} nnz)", er.nrows, er.nnz());
    engine.register("er_gemm", er).expect("register");
    let rm = rmat(12, 8.0, 0.57, 0.19, 0.19, &mut rng);
    println!("registered rmat_gemm ({} rows, {} nnz)", rm.nrows, rm.nnz());
    engine.register("rmat_gemm", rm).expect("register");
    let band = banded(scaled(1 << 16), 6, 0.4, &mut rng);
    println!("registered banded_gemm ({} rows, {} nnz)", band.nrows, band.nnz());
    engine.register("banded_gemm", band).expect("register");
    let mesh_side = ((scaled(1 << 14) as f64).sqrt() as usize).max(16);
    let mesh = mesh2d(mesh_side, MeshKind::Road, 0.62, &mut rng);
    println!("registered mesh_gemm ({} rows, {} nnz)", mesh.nrows, mesh.nnz());
    engine.register("mesh_gemm", mesh).expect("register");

    let names = ["er_gemm", "rmat_gemm", "banded_gemm", "mesh_gemm"];
    println!("\n— routing A·A per matrix (both kernels measured) —");
    for name in names {
        let rec = engine
            .submit_spgemm(&SpGemmSpec::new(name, name))
            .expect("spgemm job");
        println!(
            "  {name}·{name}: → {} (cf {:.1}, nnz(C) {}, pred {:.2} meas {:.2} GFLOP/s, ratio {:.2})",
            rec.chosen,
            rec.cf,
            rec.nnz_c,
            rec.predicted_gflops,
            rec.measured_gflops,
            rec.prediction_ratio()
        );
    }
    for dec in engine.autotuner().spgemm_decisions() {
        println!("  decision: {}", dec.summary());
        assert_eq!(dec.explored, 2, "both SpGEMM kernels must be measured");
    }

    // re-submission serves pinned decisions: no new exploration
    let n_explore = engine.autotuner().measurements();
    for name in names {
        engine.submit_spgemm(&SpGemmSpec::new(name, name)).expect("warm spgemm job");
    }
    assert_eq!(
        engine.autotuner().measurements(),
        n_explore,
        "re-submission must explore nothing (decisions pinned)"
    );

    // Artifact: per-candidate predicted-vs-measured records; count
    // foreign records before/after to prove the merge preserves them.
    let prior = std::fs::read_to_string("BENCH_route.json")
        .ok()
        .and_then(|t| PerfLog::parse(&t).ok())
        .unwrap_or_default();
    let foreign_before =
        prior.records.iter().filter(|r| r.bench != "bench_spgemm").count();

    let mut log = PerfLog::new();
    for dec in engine.autotuner().spgemm_decisions() {
        for cand in &dec.candidates {
            log.push(PerfRecord {
                predicted_gflops: cand.predicted_gflops,
                ..PerfRecord::basic(
                    "bench_spgemm",
                    format!("{}x{}", dec.a, dec.b),
                    dec.class.to_string(),
                    cand.im.to_string(),
                    0,
                    0,
                    cand.measured_gflops,
                )
            });
        }
    }
    log.merge_save("BENCH_route.json").expect("write BENCH_route.json");

    let merged = PerfLog::parse(&std::fs::read_to_string("BENCH_route.json").unwrap())
        .expect("re-parse artifact");
    let foreign_after =
        merged.records.iter().filter(|r| r.bench != "bench_spgemm").count();
    assert_eq!(
        foreign_before, foreign_after,
        "merge_save must preserve other benches' records"
    );
    let own = merged.records.iter().filter(|r| r.bench == "bench_spgemm").count();
    assert_eq!(own, log.records.len(), "all bench_spgemm records must land");
    assert!(own >= 2 * names.len(), "≥ 2 candidate records per pair");
    println!(
        "wrote BENCH_route.json ({} bench_spgemm records, {} foreign records preserved)",
        own, foreign_after
    );
}
