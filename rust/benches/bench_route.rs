//! Bench: the structure-adaptive autotuning router end to end.
//!
//! Registers a generated suite spanning all four sparsity classes plus
//! a scrambled mesh (the case where reordering — not just format —
//! decides performance), then:
//!
//! 1. **tuning batch** — first submission per (matrix, d) explores the
//!    top-k predicted (impl × reordering) candidates, feeds every
//!    measurement into the planner's priors, and pins the winner
//!    (converting the stored matrix where a reordering won);
//! 2. **pinned batch** — the identical queue re-submitted: zero
//!    exploration, schedules served from cache (both are printed and
//!    checked);
//! 3. **always-CSR baseline** — the same jobs forced to CSR on a
//!    *separate* engine holding the matrices as registered (no pinned
//!    permutations — otherwise the baseline would silently inherit the
//!    router's reordering wins), for the batch-total comparison the
//!    router must not lose.
//!
//! 4. **learned re-route** — trains the learned structure router on
//!    the accumulated `BENCH_route.json` records, re-routes the same
//!    queue on a fresh engine, and prints the per-structure-group
//!    regret-vs-analytic table (what trusting the forest cost against
//!    the measured analytic pick — 0 where analytic routed).
//!
//! Writes one `BENCH_route.json` record per pinned decision (chosen
//! impl, reordering, predicted vs measured GFLOP/s, routing source,
//! and the structural features the decision was made on — the learned
//! router's training set) via the merging perf log; the learned leg's
//! records land under the separate bench name `bench_route_learned`
//! so they never clobber the analytic training records.
//!
//! `REPRO_SCALE` (default 0.25) and `REPRO_ITERS` (default 3) tune
//! runtime; `REPRO_FAST=1` injects nominal machine parameters instead
//! of running STREAM (CI smoke mode). `REPRO_STRICT=1` exits nonzero
//! if the routed batch total falls below the always-CSR baseline
//! (kept opt-in: CI runners are too noisy for a hard perf gate).

use std::collections::BTreeMap;

use spmm_roofline::coordinator::{
    AutotunePolicy, Engine, EngineConfig, JobSpec, RouteDecision, RouteSource, TrainConfig,
};
use spmm_roofline::gen::{representative_suite, suite, Prng};
use spmm_roofline::model::{FeatureVec, MachineParams};
use spmm_roofline::report::{PerfLog, PerfRecord};
use spmm_roofline::sparse::reorder::{permute_symmetric, random_permutation};
use spmm_roofline::spmm::Impl;

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env1(key: &str) -> bool {
    std::env::var(key).map(|v| v == "1").unwrap_or(false)
}

/// One perf record per pinned decision. The decision's structural
/// features ride along (raw fractions + exact un-log-scaled counts) so
/// the learned router can train on the accumulated artifact; `source`
/// records which model ranked the explore order.
fn record_of(bench: &str, dec: &RouteDecision) -> PerfRecord {
    PerfRecord {
        reorder: dec.reorder.to_string(),
        predicted_gflops: dec.predicted_gflops,
        source: dec.source.to_string(),
        cv: dec.features.0[0],
        hub: dec.features.0[1],
        diag: dec.features.0[2],
        block: dec.features.0[3],
        n: FeatureVec::count_of(dec.features.0[4]),
        nnz: FeatureVec::count_of(dec.features.0[5]),
        ..PerfRecord::basic(
            bench,
            dec.matrix.clone(),
            dec.class.to_string(),
            dec.im.to_string(),
            dec.d,
            dec.dt.min(dec.d),
            dec.measured_gflops,
        )
    }
}

fn main() {
    let scale = envf("REPRO_SCALE", 0.25);
    let iters = envf("REPRO_ITERS", 3.0) as usize;
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let machine = if env1("REPRO_FAST") {
        Some(MachineParams { beta_gbs: 25.0, pi_gflops: 100.0 })
    } else {
        None
    };
    let mut engine = Engine::new(EngineConfig {
        threads,
        machine,
        iters,
        warmup: 1,
        // the paper trio plus the propagation-blocking kernel — the
        // structure-adversarial candidate the router must arbitrate
        impls: vec![Impl::Csr, Impl::Opt, Impl::Csb, Impl::Pb],
        artifacts_dir: Some("artifacts".into()),
        autotune: AutotunePolicy::enabled(),
    })
    .expect("engine construction");
    println!(
        "router: β={:.1} GB/s π={:.0} GFLOP/s, {} threads",
        engine.machine().beta_gbs,
        engine.machine().pi_gflops,
        threads
    );

    for proxy in representative_suite() {
        let m = proxy.generate(scale);
        println!("registered {} ({} rows, {} nnz)", proxy.name, m.nrows, m.nnz());
        engine.register(proxy.name, m).expect("register");
    }
    // the reordering showcase: a mesh whose structure was destroyed by
    // a random permutation — RCM can win it back
    let mut rng = Prng::new(0x0de7);
    let mesh = suite::find("road_usa_p").expect("suite entry").generate(scale);
    let scrambled = permute_symmetric(&mesh, &random_permutation(mesh.nrows, &mut rng));
    println!("registered road_scrambled ({} rows, {} nnz)", scrambled.nrows, scrambled.nnz());
    engine.register("road_scrambled", scrambled).expect("register");

    let names: Vec<String> = engine.registry().names().iter().map(|s| s.to_string()).collect();
    let mut jobs = Vec::new();
    for name in &names {
        for d in [4usize, 16, 64] {
            jobs.push(JobSpec::new(name.clone(), d));
        }
    }

    println!("\n— batch 1: tuning (explore top-k per matrix × d) —");
    let tuned = engine.submit_batch(&jobs).expect("tuning batch");
    println!("  {}", tuned.summary_line());
    for dec in engine.autotuner().decisions() {
        println!("  {}", dec.summary());
    }

    println!("\n— batch 2: pinned (same queue, decisions cached) —");
    let routed = engine.submit_batch(&jobs).expect("pinned batch");
    println!("  {}", routed.summary_line());
    println!(
        "  explored: {} → {} (pinned), schedule hit rate {:.0}%",
        tuned.explore_measurements,
        routed.explore_measurements,
        100.0 * routed.schedule_hit_rate()
    );
    assert_eq!(
        routed.explore_measurements, 0,
        "re-submitting the same batch must not re-measure candidates"
    );

    // The baseline runs on a fresh engine: the tuned engine's matrices
    // were permuted in place where a reordering won, and CSR-on-the-
    // pinned-layout would inherit exactly the benefit being measured.
    // Same generators + seeds → identical original matrices; the tuned
    // engine's measured machine parameters avoid a second STREAM run.
    println!("\n— batch 3: always-CSR baseline (original layouts, fresh engine) —");
    let mut base_engine = Engine::new(EngineConfig {
        threads,
        machine: Some(engine.machine()),
        iters,
        warmup: 1,
        impls: vec![Impl::Csr],
        artifacts_dir: None,
        autotune: AutotunePolicy::default(),
    })
    .expect("baseline engine");
    for proxy in representative_suite() {
        base_engine.register(proxy.name, proxy.generate(scale)).expect("register");
    }
    let mut rng = Prng::new(0x0de7);
    let mesh = suite::find("road_usa_p").expect("suite entry").generate(scale);
    let scrambled = permute_symmetric(&mesh, &random_permutation(mesh.nrows, &mut rng));
    base_engine.register("road_scrambled", scrambled).expect("register");
    let csr_jobs: Vec<JobSpec> = jobs.iter().map(|j| j.clone().with_impl(Impl::Csr)).collect();
    base_engine.submit_batch(&csr_jobs).expect("baseline warmup"); // warm buffers + schedules
    let baseline = base_engine.submit_batch(&csr_jobs).expect("baseline batch");
    println!("  {}", baseline.summary_line());

    let routed_gf = routed.aggregate_gflops();
    let baseline_gf = baseline.aggregate_gflops();
    println!(
        "\nrouted {routed_gf:.2} GFLOP/s vs always-CSR {baseline_gf:.2} GFLOP/s → {:.2}× \
         on the batch total",
        routed_gf / baseline_gf.max(1e-12)
    );
    if env1("REPRO_STRICT") && routed_gf < baseline_gf {
        eprintln!("STRICT: router lost to the always-CSR baseline");
        std::process::exit(1);
    }

    let mut log = PerfLog::new();
    for dec in engine.autotuner().decisions() {
        log.push(record_of("bench_route", dec));
    }
    log.merge_save("BENCH_route.json").expect("write BENCH_route.json");
    println!("wrote BENCH_route.json ({} routing records)", log.records.len());

    // — batch 4: the learned leg. Train the structure router on the
    // *accumulated* artifact (this run's records merged with whatever
    // earlier runs left behind), stand up a fresh engine holding the
    // original layouts, and re-route the identical queue — the forest
    // promotes its predicted winner where it is confident and
    // in-distribution, the analytic model routes the rest, and the
    // per-structure-group table reports what trusting the forest cost
    // against the measured analytic pick.
    println!("\n— batch 4: learned re-route (forest trained on BENCH_route.json) —");
    let accumulated = std::fs::read_to_string("BENCH_route.json")
        .ok()
        .and_then(|t| PerfLog::parse(&t).ok())
        .unwrap_or_default();
    let mut learned_engine = Engine::new(EngineConfig {
        threads,
        machine: Some(engine.machine()),
        iters,
        warmup: 1,
        impls: vec![Impl::Csr, Impl::Opt, Impl::Csb, Impl::Pb],
        artifacts_dir: None,
        autotune: AutotunePolicy::enabled(),
    })
    .expect("learned engine");
    for proxy in representative_suite() {
        learned_engine.register(proxy.name, proxy.generate(scale)).expect("register");
    }
    let mut rng = Prng::new(0x0de7);
    let mesh = suite::find("road_usa_p").expect("suite entry").generate(scale);
    let scrambled = permute_symmetric(&mesh, &random_permutation(mesh.nrows, &mut rng));
    learned_engine.register("road_scrambled", scrambled).expect("register");
    // min_support 1: the bench suites are small (tens of records), and
    // a single-example leaf at an exactly-reproduced training point is
    // precisely the interpolation the gate should admit here
    let cfg = TrainConfig { min_support: 1, ..TrainConfig::default() };
    match learned_engine.train_learned_router(&accumulated, &cfg) {
        Ok(n) => println!(
            "  trained on {n} examples: {}",
            learned_engine.learned_router().expect("just installed").summary()
        ),
        Err(e) => println!("  learned leg skipped ({e})"),
    }
    let relearned = learned_engine.submit_batch(&jobs).expect("learned batch");
    println!("  {}", relearned.summary_line());

    // per-structure-group regret-vs-analytic table
    let mut groups: BTreeMap<String, (usize, usize, f64)> = BTreeMap::new();
    for dec in learned_engine.autotuner().decisions() {
        let g = groups.entry(dec.class.to_string()).or_insert((0, 0, 0.0));
        g.0 += 1;
        if dec.source == RouteSource::Learned {
            g.1 += 1;
        }
        g.2 += dec.regret_vs_analytic();
    }
    println!("\n  regret-vs-analytic by structure group:");
    println!("  {:<16} {:>7} {:>8} {:>22}", "class", "routes", "learned", "mean regret GFLOP/s");
    for (class, (routes, learned, regret)) in &groups {
        println!(
            "  {class:<16} {routes:>7} {learned:>8} {:>22.4}",
            regret / (*routes as f64).max(1.0)
        );
    }

    let mut learned_log = PerfLog::new();
    for dec in learned_engine.autotuner().decisions() {
        learned_log.push(record_of("bench_route_learned", dec));
    }
    learned_log.merge_save("BENCH_route.json").expect("write BENCH_route.json");
    println!(
        "wrote BENCH_route.json ({} learned re-route records)",
        learned_log.records.len()
    );
}
