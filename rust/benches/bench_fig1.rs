//! Bench: regenerate the paper's Fig. 1 (GFLOP/s vs dense width d for
//! one representative matrix per sparsity class).
//!
//! Uses a denser d grid than the paper's table so the curves are
//! smooth. Writes `results/fig1_*.svg` + `results/fig1.csv`.

use spmm_roofline::config::ExperimentConfig;
use spmm_roofline::harness::run_fig1;
use spmm_roofline::spmm::Impl;

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let cfg = ExperimentConfig {
        scale: envf("REPRO_SCALE", 0.25),
        iters: envf("REPRO_ITERS", 3.0) as usize,
        warmup: 1,
        d_values: vec![1, 2, 4, 8, 16, 32, 64],
        ..Default::default()
    };
    eprintln!("bench_fig1: scale={} iters={}", cfg.scale, cfg.iters);
    let data = run_fig1(&cfg).expect("fig1 sweep failed");
    println!("{}", data.render().to_text());
    data.save_svgs("results").expect("svg write failed");
    data.save_csv("results/fig1.csv").expect("csv write failed");
    println!("wrote results/fig1_*.svg and results/fig1.csv");

    // the paper's headline observation: perf improves with d, peaking
    // near d = 32..64
    for (name, _, _) in &data.matrices {
        for im in [Impl::Csr, Impl::Opt, Impl::Csb] {
            if let Some(best) = data.best_d(name, im) {
                println!("  best d for {name}/{im}: {best}");
            }
        }
    }
}
