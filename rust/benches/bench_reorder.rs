//! Bench A4: matrix reordering moves matrices between structural
//! regimes — classification, model AI, and measured performance must
//! move together (the paper's core premise driven from the other
//! direction).

use spmm_roofline::config::ExperimentConfig;
use spmm_roofline::harness::ablate_reorder;

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let cfg = ExperimentConfig {
        scale: envf("REPRO_SCALE", 0.25),
        iters: envf("REPRO_ITERS", 3.0) as usize,
        warmup: 1,
        ..Default::default()
    };
    for d in [4usize, 16] {
        let t = ablate_reorder(&cfg, d).expect("reorder ablation failed");
        println!("{}", t.to_text());
    }
    println!("expectations: random ordering drops the mesh to the Random class and");
    println!("its measured GFLOP/s; RCM restores bandedness (Diagonal/Blocked).");
}
