//! Bench A1: CSB block-size sweep — performance and the
//! `z = t(1 − e^{−D/t})` occupancy statistics vs block dimension, on a
//! blocked mesh and a uniform-random matrix.

use spmm_roofline::config::ExperimentConfig;
use spmm_roofline::harness::ablate_block_size;

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let cfg = ExperimentConfig {
        scale: envf("REPRO_SCALE", 0.25),
        iters: envf("REPRO_ITERS", 3.0) as usize,
        warmup: 1,
        ..Default::default()
    };
    let dims = [64usize, 256, 1024, 4096, 16384];
    for matrix in ["road_usa_p", "er_18_10", "com_lj_p"] {
        for d in [4usize, 64] {
            let (t, _) =
                ablate_block_size(&cfg, matrix, d, &dims).expect("block ablation failed");
            println!("{}", t.to_text());
        }
    }
}
