//! Bench A3: thread scaling of the three native kernels.
//!
//! This testbed exposes a single physical core, so the sweep measures
//! scheduling overhead rather than parallel speedup — documented as
//! such in EXPERIMENTS.md (the paper used 64 threads on 64 cores).

use spmm_roofline::config::ExperimentConfig;
use spmm_roofline::harness::ablate_threads;

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let cfg = ExperimentConfig {
        scale: envf("REPRO_SCALE", 0.25),
        iters: envf("REPRO_ITERS", 3.0) as usize,
        warmup: 1,
        ..Default::default()
    };
    for matrix in ["er_18_10", "road_usa_p"] {
        let t = ablate_threads(&cfg, matrix, 16, &[1, 2, 4, 8]).expect("thread ablation failed");
        println!("{}", t.to_text());
    }
    println!(
        "hardware threads available: {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
}
