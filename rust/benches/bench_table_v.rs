//! Bench: regenerate the paper's Table V (SpMM GFLOP/s across
//! formats × d) on the proxy dataset.
//!
//! `REPRO_SCALE` (default 0.25) and `REPRO_ITERS` (default 3) tune
//! runtime; `cargo bench --bench bench_table_v` writes
//! `results/table_v.csv` alongside the printed table and the paper's
//! shape checks.

use spmm_roofline::config::ExperimentConfig;
use spmm_roofline::harness::{paper_table_v, run_table_v};

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let cfg = ExperimentConfig {
        scale: envf("REPRO_SCALE", 0.25),
        iters: envf("REPRO_ITERS", 3.0) as usize,
        warmup: 1,
        ..Default::default()
    };
    eprintln!(
        "bench_table_v: scale={} iters={} threads={}",
        cfg.scale, cfg.iters, cfg.threads
    );
    let data = run_table_v(&cfg).expect("table v sweep failed");
    println!("{}", data.render(&cfg).to_text());
    println!("shape checks vs the paper's §IV-C claims:");
    for (desc, ok) in data.shape_checks(&cfg) {
        println!("  [{}] {desc}", if ok { "PASS" } else { "FAIL" });
    }
    data.save_csv("results/table_v.csv").expect("csv write failed");
    println!("wrote results/table_v.csv");

    // side-by-side with the paper for representative cells
    let paper = paper_table_v();
    println!("\npaper-vs-proxy spot cells (GFLOP/s — absolute numbers differ, shape should hold):");
    for (name, proxy_name) in [
        ("road_usa", "road_usa_p"),
        ("com-LiveJournal", "com_lj_p"),
        ("rajat31", "rajat31_p"),
        ("er_22_10", "er_18_10"),
    ] {
        for d in [1usize, 64] {
            let p = paper
                .iter()
                .find(|(n, dd, im, _)| *n == name && *dd == d && *im == "CSB")
                .map(|x| x.3)
                .unwrap_or(0.0);
            let ours = data
                .get(proxy_name, d, spmm_roofline::spmm::Impl::Csb)
                .unwrap_or(0.0);
            println!("  {name:>18} d={d:<3} CSB paper={p:>8.2} ours={ours:>8.2}");
        }
    }
}
