//! Bench: the tile sweep — model-chosen column tiling vs untiled
//! execution, per sparsity class × implementation × dense width.
//!
//! This is the schedule layer's acceptance gauge (mirrors the paper's
//! varying-`d` experiments, Fig. 1): for one small matrix per sparsity
//! class it plans a schedule with the planner's model-chosen tile
//! width and executes it against the untiled (`dt = d`) schedule on
//! the same kernel. The model-chosen tile must never lose to untiled
//! by more than noise — and should win on blocked/banded workloads at
//! `d ≥ 64`, where the full `B` working set falls out of cache.
//!
//! Writes per-cell records (both tile widths) into
//! `BENCH_schedule.json` via the merging perf log, so the repo's perf
//! trajectory is tracked across PRs.
//!
//! `REPRO_SCALE` (default 0.25) and `REPRO_ITERS` (default 3) tune
//! runtime; `REPRO_FAST=1` injects nominal machine parameters instead
//! of running STREAM (CI smoke mode).

use spmm_roofline::coordinator::Planner;
use spmm_roofline::gen::representative_suite;
use spmm_roofline::membench;
use spmm_roofline::metrics::{bench_adaptive, gflops, spmm_flops};
use spmm_roofline::model::{MachineParams, Roofline};
use spmm_roofline::pattern::classify;
use spmm_roofline::report::{PerfLog, PerfRecord, Table};
use spmm_roofline::spmm::{build_native, DenseMatrix, Impl};

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let scale = envf("REPRO_SCALE", 0.25);
    let iters = envf("REPRO_ITERS", 3.0) as usize;
    let fast = std::env::var("REPRO_FAST").map(|v| v == "1").unwrap_or(false);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    let machine = if fast {
        MachineParams { beta_gbs: 25.0, pi_gflops: 100.0 }
    } else {
        membench::measure_machine(threads)
    };
    let planner = Planner::new(Roofline::new(machine));
    println!(
        "tile sweep: scale={scale}, {threads} threads, β={:.1} GB/s π={:.0} GFLOP/s",
        machine.beta_gbs, machine.pi_gflops
    );

    let mut t = Table::new(
        "tile sweep — model-chosen dt vs untiled (GFLOP/s)",
        &["Matrix", "Class", "Impl", "d", "dt", "tiled", "untiled", "speedup"],
    );
    let mut log = PerfLog::new();
    let mut rng = spmm_roofline::gen::Prng::new(0x5c4ed);

    for proxy in representative_suite() {
        let a = proxy.generate(scale);
        let cls = classify(&a);
        for im in Impl::NATIVE {
            let kernel = build_native(im, &a, threads).expect("native kernel");
            for d in [16usize, 64, 128] {
                let pred = planner.predict(&cls, d, im);
                let b = DenseMatrix::random(a.ncols, d, &mut rng);
                let mut c = DenseMatrix::zeros(a.nrows, d);
                let tiled_plan = kernel.plan(Some(pred.dt).filter(|&dt| dt < d));
                let untiled_plan = kernel.plan(None);
                let flops = spmm_flops(kernel.nnz(), d);

                let rt = bench_adaptive(1, iters, iters * 4, 0.1, |_| {
                    kernel.execute_with(&b, &mut c, &tiled_plan).expect("tiled exec");
                });
                let gf_tiled = gflops(flops, rt.median_secs());
                let ru = bench_adaptive(1, iters, iters * 4, 0.1, |_| {
                    kernel.execute_with(&b, &mut c, &untiled_plan).expect("untiled exec");
                });
                let gf_untiled = gflops(flops, ru.median_secs());

                t.row(vec![
                    proxy.name.into(),
                    cls.class.to_string(),
                    im.to_string(),
                    d.to_string(),
                    if pred.dt >= d { "—".into() } else { pred.dt.to_string() },
                    format!("{gf_tiled:.2}"),
                    format!("{gf_untiled:.2}"),
                    format!("{:.2}×", gf_tiled / gf_untiled.max(1e-12)),
                ]);
                log.push(PerfRecord::basic(
                    "bench_schedule",
                    proxy.name,
                    cls.class.to_string(),
                    im.to_string(),
                    d,
                    pred.dt.min(d),
                    gf_tiled,
                ));
                log.push(PerfRecord::basic(
                    "bench_schedule",
                    proxy.name,
                    cls.class.to_string(),
                    im.to_string(),
                    d,
                    d,
                    gf_untiled,
                ));
            }
        }
    }
    println!("{}", t.to_text());
    log.merge_save("BENCH_schedule.json").expect("write BENCH_schedule.json");
    println!("wrote BENCH_schedule.json ({} records)", log.records.len());
}
