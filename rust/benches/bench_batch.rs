//! Bench: the batched engine path — a (matrix × d) job queue routed
//! through `Engine::submit_batch`, with the persistent worker pool and
//! the recycled dense buffers staying warm across the whole queue.
//!
//! Reports the per-job routing table, then the batch aggregate:
//! throughput over kernel-execution time, model-prediction error,
//! buffer-pool hit rate, and the dispatch-overhead fraction
//! (wall time not spent inside kernels). A second identical batch runs
//! fully warm, so the printed delta isolates what batching amortises.
//!
//! `REPRO_SCALE` (default 0.25) and `REPRO_ITERS` (default 3) tune
//! runtime. Machine β/π are measured (STREAM + FMA) unless
//! `REPRO_FAST=1` injects nominal parameters to skip calibration.

use spmm_roofline::coordinator::{Engine, EngineConfig, JobSpec};
use spmm_roofline::gen::representative_suite;
use spmm_roofline::model::MachineParams;
use spmm_roofline::report::{PerfLog, PerfRecord};
use spmm_roofline::spmm::{pool, Impl};

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let scale = envf("REPRO_SCALE", 0.25);
    let iters = envf("REPRO_ITERS", 3.0) as usize;
    let fast = std::env::var("REPRO_FAST").map(|v| v == "1").unwrap_or(false);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    let machine = if fast {
        // nominal parameters: predictions are indicative only, but the
        // measured aggregate numbers are unaffected
        Some(MachineParams { beta_gbs: 25.0, pi_gflops: 100.0 })
    } else {
        None // calibrate via STREAM + FMA loop
    };
    let mut engine = Engine::new(EngineConfig {
        threads,
        machine,
        iters,
        warmup: 1,
        impls: vec![Impl::Csr, Impl::Opt, Impl::Csb],
        artifacts_dir: Some("artifacts".into()),
        ..EngineConfig::default()
    })
    .expect("engine construction");
    println!(
        "engine: β={:.1} GB/s π={:.0} GFLOP/s, {} threads, pool: {} persistent workers, xla={}",
        engine.machine().beta_gbs,
        engine.machine().pi_gflops,
        threads,
        pool::global().workers(),
        engine.has_xla()
    );

    for proxy in representative_suite() {
        let m = proxy.generate(scale);
        println!("registered {} ({} rows, {} nnz)", proxy.name, m.nrows, m.nnz());
        engine.register(proxy.name, m).expect("register");
    }

    let names: Vec<String> = engine.registry().names().iter().map(|s| s.to_string()).collect();
    let mut jobs = Vec::new();
    for name in &names {
        for d in [1usize, 4, 16, 64] {
            jobs.push(JobSpec::new(name.clone(), d));
        }
    }

    println!("\n— batch 1 (cold buffers) —");
    let cold = engine.submit_batch(&jobs).expect("batch");
    for r in &cold.records {
        let chosen = r.chosen.to_string();
        println!(
            "  {:<12} d={:<3} → {chosen:<4} pred {:>7.2}  meas {:>7.2} GFLOP/s  ratio {:.2}",
            r.matrix, r.d, r.predicted_gflops, r.measured_gflops,
            r.prediction_ratio()
        );
    }
    println!("  {}", cold.summary_line());
    println!(
        "  exec {:.1} ms of {:.1} ms wall → dispatch overhead {:.1}%",
        cold.exec_secs * 1e3,
        cold.wall_secs * 1e3,
        100.0 * cold.dispatch_overhead()
    );

    println!("\n— batch 2 (warm: buffers + schedules + priors reused) —");
    let warm = engine.submit_batch(&jobs).expect("batch");
    println!("  {}", warm.summary_line());
    println!(
        "  buffer misses cold {} → warm {}; schedule misses cold {} → warm {}; \
         aggregate {:.2} → {:.2} GFLOP/s",
        cold.buffer_misses,
        warm.buffer_misses,
        cold.schedule_misses,
        warm.schedule_misses,
        cold.aggregate_gflops(),
        warm.aggregate_gflops()
    );
    let rep = engine.prediction_report();
    println!(
        "\nprediction over both batches: n={} geomean(meas/pred)={:.2} mean|log err|={:.2}",
        rep.n_jobs, rep.geomean_ratio, rep.mean_abs_log_err
    );

    // machine-readable perf artifact: the warm batch's per-job cells
    let mut log = PerfLog::new();
    for r in &warm.records {
        log.push(PerfRecord {
            reorder: r.reorder.to_string(),
            predicted_gflops: r.predicted_gflops,
            ..PerfRecord::basic(
                "bench_batch",
                r.matrix.clone(),
                r.class.to_string(),
                r.chosen.to_string(),
                r.d,
                r.dt.min(r.d),
                r.measured_gflops,
            )
        });
    }
    log.merge_save("BENCH_schedule.json").expect("write BENCH_schedule.json");
    println!("wrote BENCH_schedule.json ({} bench_batch records)", log.records.len());
}
