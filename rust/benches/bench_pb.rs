//! Bench: the propagation-blocking kernel through the adaptive router.
//!
//! PB is the first kernel whose predicted win/loss flips with
//! structure: its traffic model (`model/pb.rs`) is
//! structure-independent, so it ranks inside the router's explored
//! top-k on random/scale-free matrices (where the gathering kernels'
//! priors collapse) and far outside it on banded/blocked ones. This
//! bench drives that flip end to end:
//!
//! 1. registers a **showcase random matrix** sized so `B` is
//!    DRAM-resident even in smoke mode (`n` floored at 2¹⁸ — PB's win
//!    condition cannot exist on a cache-resident `B`), plus an R-MAT
//!    (scale-free-ish), a banded and a mesh proxy at the configured
//!    scale for contrast;
//! 2. autotunes every `(matrix, d)` with reordering fixed to `none`
//!    and `top_k` covering the whole format space, so PB is *measured*
//!    everywhere its prediction earns a look;
//! 3. prints the pinned decisions and whether any random/scale-free
//!    matrix routed to PB (`REPRO_STRICT=1` turns that expectation
//!    into a hard exit code — kept opt-in, because on hosts with very
//!    large L3 the showcase `B` may still be cache-resident and PB
//!    honestly loses);
//! 4. appends one `BENCH_route.json` record per pinned decision plus
//!    one forced-PB record per `(matrix, d)` (bench = `bench_pb`), so
//!    PB's predicted-vs-measured line is tracked across PRs whether or
//!    not it wins, and asserts the merge preserved every other
//!    bench's records.
//!
//! `REPRO_SCALE` (default 0.25) and `REPRO_ITERS` (default 3) tune
//! runtime; `REPRO_FAST=1` injects nominal machine parameters instead
//! of running STREAM (CI smoke mode).

use spmm_roofline::coordinator::{AutotunePolicy, Engine, EngineConfig, JobSpec};
use spmm_roofline::gen::{banded, erdos_renyi, mesh2d, rmat, MeshKind, Prng};
use spmm_roofline::model::MachineParams;
use spmm_roofline::report::{PerfLog, PerfRecord};
use spmm_roofline::sparse::Reordering;
use spmm_roofline::spmm::Impl;

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env1(key: &str) -> bool {
    std::env::var(key).map(|v| v == "1").unwrap_or(false)
}

fn main() {
    let scale = envf("REPRO_SCALE", 0.25);
    let iters = envf("REPRO_ITERS", 3.0) as usize;
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let machine = if env1("REPRO_FAST") {
        Some(MachineParams { beta_gbs: 25.0, pi_gflops: 100.0 })
    } else {
        None
    };
    let mut engine = Engine::new(EngineConfig {
        threads,
        machine,
        iters,
        warmup: 1,
        impls: vec![Impl::Csr, Impl::Opt, Impl::Csb, Impl::Pb],
        artifacts_dir: None,
        autotune: AutotunePolicy {
            enabled: true,
            // measure every format candidate: the question is whether
            // PB's measurement confirms its structure-independent
            // prediction, not whether it squeaked into a top-3
            top_k: 16,
            // format choice is PB's axis; reordering exploration would
            // only add noise (and RCM on the showcase sizes, time)
            reorderings: vec![Reordering::None],
            explore_iters: iters.max(1),
            explore_min_secs: 0.02,
        },
    })
    .expect("engine construction");
    println!(
        "PB bench: β={:.1} GB/s π={:.0} GFLOP/s, {} threads, scale={scale}",
        engine.machine().beta_gbs,
        engine.machine().pi_gflops,
        threads
    );

    let mut rng = Prng::new(0x9b9b);
    // The showcase: a uniform-random matrix whose dense operand is
    // DRAM-resident. 8·n·d at n = 2¹⁸, d = 4 is 8 MiB — beyond the
    // halved-L2 residency threshold everywhere and beyond L3 on most
    // hosts. Floored, not scaled: PB's win condition does not exist at
    // cache-resident smoke sizes.
    let n_random = (((1u64 << 20) as f64 * scale) as usize).max(1 << 18);
    let er = erdos_renyi(n_random, n_random, 16.0, &mut rng);
    println!("registered er_pb ({} rows, {} nnz)", er.nrows, er.nnz());
    engine.register("er_pb", er).expect("register");
    let rm = rmat(14, 12.0, 0.57, 0.19, 0.19, &mut rng);
    println!("registered rmat_pb ({} rows, {} nnz)", rm.nrows, rm.nnz());
    engine.register("rmat_pb", rm).expect("register");
    // contrast set: structures whose models keep PB out of the top-k
    let scaled = |base: usize| ((base as f64 * scale) as usize).max(256);
    let band = banded(scaled(1 << 16), 8, 0.4, &mut rng);
    println!("registered banded_pb ({} rows, {} nnz)", band.nrows, band.nnz());
    engine.register("banded_pb", band).expect("register");
    let mesh_side = ((scaled(1 << 14) as f64).sqrt() as usize).max(16);
    let mesh = mesh2d(mesh_side, MeshKind::Road, 0.62, &mut rng);
    println!("registered mesh_pb ({} rows, {} nnz)", mesh.nrows, mesh.nnz());
    engine.register("mesh_pb", mesh).expect("register");

    // small d is PB's regime: random 8d-byte gathers waste most of
    // each cache line, while PB's spill traffic stays width-linear
    let mut jobs = Vec::new();
    for name in ["er_pb", "rmat_pb", "banded_pb", "mesh_pb"] {
        for d in [2usize, 4, 8] {
            jobs.push(JobSpec::new(name, d));
        }
    }

    println!("\n— tuning batch (all format candidates measured per matrix × d) —");
    let tuned = engine.submit_batch(&jobs).expect("tuning batch");
    println!("  {}", tuned.summary_line());
    for dec in engine.autotuner().decisions() {
        println!("  {}", dec.summary());
    }

    // every registered matrix must have enumerated PB as a candidate
    for name in ["er_pb", "rmat_pb", "banded_pb", "mesh_pb"] {
        let entry = engine.registry().get(name).expect("registered");
        assert!(
            entry.native_impls().contains(&Impl::Pb),
            "{name}: PB must be a prepared routing candidate"
        );
    }

    let pb_wins: Vec<String> = engine
        .autotuner()
        .decisions()
        .iter()
        .filter(|dec| dec.im == Impl::Pb)
        .map(|dec| format!("{} d={}", dec.matrix, dec.d))
        .collect();
    if pb_wins.is_empty() {
        println!(
            "\nNOTE: no (matrix, d) routed to PB on this host — expected when the \
             showcase B still fits in cache (large L3). Predictions are recorded either way."
        );
    } else {
        println!("\nrouted to PB: {}", pb_wins.join(", "));
    }
    if env1("REPRO_STRICT") && pb_wins.is_empty() {
        eprintln!("STRICT: no random/scale-free matrix routed to PB");
        std::process::exit(1);
    }

    // Artifact: pinned decisions + a forced-PB measurement per cell,
    // so BENCH_route.json carries PB's predicted-vs-measured line even
    // where it lost the routing. Count foreign records before/after to
    // prove the merge preserves them (the CI smoke gate).
    let prior = std::fs::read_to_string("BENCH_route.json")
        .ok()
        .and_then(|t| PerfLog::parse(&t).ok())
        .unwrap_or_default();
    let foreign_before = prior.records.iter().filter(|r| r.bench != "bench_pb").count();

    let mut log = PerfLog::new();
    for dec in engine.autotuner().decisions() {
        log.push(PerfRecord {
            reorder: dec.reorder.to_string(),
            predicted_gflops: dec.predicted_gflops,
            ..PerfRecord::basic(
                "bench_pb",
                dec.matrix.clone(),
                dec.class.to_string(),
                dec.im.to_string(),
                dec.d,
                dec.dt.min(dec.d),
                dec.measured_gflops,
            )
        });
    }
    println!("\n— forced-PB line (predicted vs measured per matrix × d) —");
    for job in &jobs {
        let forced = job.clone().with_impl(Impl::Pb);
        let rec = engine.submit(&forced).expect("forced PB job");
        println!(
            "  {} d={}: pred {:.2} meas {:.2} GFLOP/s (ratio {:.2})",
            rec.matrix,
            rec.d,
            rec.predicted_gflops,
            rec.measured_gflops,
            rec.prediction_ratio()
        );
        log.push(PerfRecord {
            predicted_gflops: rec.predicted_gflops,
            ..PerfRecord::basic(
                "bench_pb",
                format!("{}+forced", rec.matrix),
                rec.class.to_string(),
                Impl::Pb.to_string(),
                rec.d,
                rec.dt.min(rec.d),
                rec.measured_gflops,
            )
        });
    }
    log.merge_save("BENCH_route.json").expect("write BENCH_route.json");

    let merged = PerfLog::parse(&std::fs::read_to_string("BENCH_route.json").unwrap())
        .expect("re-parse artifact");
    let foreign_after = merged.records.iter().filter(|r| r.bench != "bench_pb").count();
    assert_eq!(
        foreign_before, foreign_after,
        "merge_save must preserve other benches' records"
    );
    let own = merged.records.iter().filter(|r| r.bench == "bench_pb").count();
    assert_eq!(own, log.records.len(), "all bench_pb records must land");
    println!(
        "wrote BENCH_route.json ({} bench_pb records, {} foreign records preserved)",
        own, foreign_after
    );
}
