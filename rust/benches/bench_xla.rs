//! Bench: the XLA/PJRT request path vs the native kernels on the same
//! arrays.
//!
//! Loads the AOT artifacts (`make artifacts` first), stages an ELL
//! matrix matching the artifacts' static shape, and measures SpMM
//! GFLOP/s end-to-end through PJRT (including the B-in / C-out literal
//! transfers a request pays) against the native ELL and CSR kernels.

use spmm_roofline::gen::{erdos_renyi, Prng};
use spmm_roofline::harness::measure_kernel;
use spmm_roofline::runtime::{ArtifactManifest, XlaRuntime, XlaSpmm};
use spmm_roofline::sparse::{Coo, Csr};
use spmm_roofline::spmm::{CsrSpmm, EllSpmm};

/// Keep at most `width` nonzeros per row (the artifact's static slot
/// budget) — preserves the random access pattern.
fn truncate_rows(a: &Csr, width: usize) -> Csr {
    let mut coo = Coo::with_capacity(a.nrows, a.ncols, a.nnz());
    for r in 0..a.nrows {
        for (k, (c, v)) in a.row_cols(r).iter().zip(a.row_vals(r)).enumerate() {
            if k >= width {
                break;
            }
            coo.push(r, *c as usize, *v);
        }
    }
    Csr::from_coo(coo)
}

fn main() {
    let manifest = match ArtifactManifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("bench_xla skipped: {e}");
            return;
        }
    };
    let rt = match XlaRuntime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            // stub runtime (built without --features xla) lands here
            eprintln!("bench_xla skipped: {e}");
            return;
        }
    };
    eprintln!("bench_xla: platform={}", rt.platform());

    let n = 16384usize;
    let width = 16usize;
    let mut rng = Prng::new(0xA17);
    let a = truncate_rows(&erdos_renyi(n, n, 10.0, &mut rng), width);
    assert!(a.max_row_len() <= width);

    println!("matrix: er n={n} nnz={} (truncated to width {width})", a.nnz());
    println!(
        "{:>4}  {:>10} {:>10} {:>10}  {:>8}",
        "d", "XLA GF/s", "ELL GF/s", "CSR GF/s", "XLA/ELL"
    );
    for d in [1usize, 4, 16, 64] {
        let spec = match manifest.find_ell(n, width, d) {
            Some(s) => s,
            None => {
                eprintln!("  no artifact for d={d}, skipping");
                continue;
            }
        };
        let xla = XlaSpmm::from_csr(&rt, spec, &a).expect("stage artifact");
        let ell = EllSpmm::from_csr(&a, 1);
        let csr = CsrSpmm::new(a.clone(), 1);
        let mx = measure_kernel(&xla, d, 3, 1).expect("measure XLA kernel");
        let me = measure_kernel(&ell, d, 3, 1).expect("measure ELL kernel");
        let mc = measure_kernel(&csr, d, 3, 1).expect("measure CSR kernel");
        println!(
            "{d:>4}  {:>10.3} {:>10.3} {:>10.3}  {:>8.2}",
            mx.gflops,
            me.gflops,
            mc.gflops,
            mx.gflops / me.gflops
        );
    }
    println!("\nnote: XLA time includes per-request literal transfers (B in, C out);");
    println!("the native ELL row shares the identical padded arrays.");
}
