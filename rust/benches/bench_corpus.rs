//! Bench: the out-of-core corpus harness end to end (EXPERIMENTS row
//! CO).
//!
//! 1. Builds a corpus directory: `CORPUS_DIR` when set, otherwise a
//!    temp tree synthesized from the proxy suite (one subdirectory per
//!    structure group) — the same tree the CI smoke job uses.
//! 2. Runs the harness: streaming MatrixMarket ingest → classify →
//!    autotune-route (tuning batch + pinned batch) → per-group report.
//! 3. Differential check on the side: the first corpus file is
//!    executed both whole-matrix ([`CsrSpmm`]) and band-by-band
//!    through a file-backed [`OocCsr`] under a budget small enough to
//!    force several bands; the outputs must be bitwise identical.
//! 4. Writes `BENCH_corpus.json` via the merging perf log and asserts
//!    foreign benches' records survive the merge.
//!
//! `REPRO_SCALE` (default 0.1) and `REPRO_ITERS` (default 2) tune
//! runtime; `REPRO_FAST=1` injects nominal machine parameters instead
//! of running STREAM (CI smoke mode).

use spmm_roofline::gen::Prng;
use spmm_roofline::harness::{ingest_dir, run_corpus, synthesize_corpus, CorpusConfig};
use spmm_roofline::model::MachineParams;
use spmm_roofline::report::{PerfLog, PerfRecord};
use spmm_roofline::sparse::{OocCsr, OocSpmm};
use spmm_roofline::spmm::{CsrSpmm, DenseMatrix, Spmm};

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env1(key: &str) -> bool {
    std::env::var(key).map(|v| v == "1").unwrap_or(false)
}

fn main() {
    let scale = envf("REPRO_SCALE", 0.1);
    let iters = envf("REPRO_ITERS", 2.0) as usize;
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let machine = if env1("REPRO_FAST") {
        Some(MachineParams { beta_gbs: 25.0, pi_gflops: 100.0 })
    } else {
        None
    };

    let dir = match std::env::var("CORPUS_DIR") {
        Ok(d) => std::path::PathBuf::from(d),
        Err(_) => {
            let d = std::env::temp_dir().join("spmm_roofline_bench_corpus");
            let _ = std::fs::remove_dir_all(&d);
            d
        }
    };
    std::fs::create_dir_all(&dir).expect("corpus dir");
    let ingested = ingest_dir(&dir).expect("corpus dir walk");
    let synthesized_tree = ingested.is_empty();
    if synthesized_tree {
        let written = synthesize_corpus(&dir, scale).expect("synthesize corpus");
        println!("synthesized {} .mtx files under {}", written.len(), dir.display());
    } else {
        println!("found {} .mtx files under {}", ingested.len(), dir.display());
    }

    // differential check: whole-matrix vs file-backed band-by-band on
    // the first corpus file, budget forcing several bands
    let files = ingest_dir(&dir).expect("corpus dir walk");
    let (name, path, csr) = &files[0];
    let d = 8;
    let budget = spmm_roofline::sparse::mm_io::band_bytes(csr.nrows, csr.nnz()) / 4 + 1;
    let ooc = OocCsr::open(path, budget).expect("ooc open");
    assert!(ooc.n_bands() >= 2, "{name}: budget must force multiple bands");
    let b = DenseMatrix::random(csr.ncols, d, &mut Prng::new(0xc0c0));
    let mut c_whole = DenseMatrix::zeros(csr.nrows, d);
    let mut c_banded = DenseMatrix::zeros(csr.nrows, d);
    CsrSpmm::new(csr.clone(), threads).execute(&b, &mut c_whole).expect("whole-matrix SpMM");
    let kern = OocSpmm::new(ooc, threads);
    kern.execute(&b, &mut c_banded).expect("banded SpMM");
    assert_eq!(
        c_whole.data, c_banded.data,
        "{name}: band-by-band execution must be bitwise identical"
    );
    println!(
        "ooc differential: {name} in {} bands (budget {budget} B) — bitwise identical",
        kern.backing().n_bands()
    );

    let rep = run_corpus(&CorpusConfig {
        dir: Some(dir),
        scale,
        threads,
        iters,
        warmup: 1,
        d_values: vec![4, 16],
        machine,
        ooc_budget: budget,
    })
    .expect("corpus run");
    assert!(!rep.synthesized, "bench corpus dir was just populated");
    println!("{}", rep.matrix_table().to_text());
    println!("{}", rep.group_table().to_text());
    assert_eq!(
        rep.pinned_explores, 0,
        "pinned re-submission must serve decisions without exploring"
    );
    assert_eq!(rep.rows.len(), rep.matrices.len() * 2, "one row per matrix × d");
    if synthesized_tree {
        // the synthesized proxy corpus spans all four structure groups
        for class in ["Uniform Random", "Diagonal", "Blocking", "Scale-free"] {
            assert!(
                rep.groups.iter().any(|g| g.class == class),
                "missing structure group {class}"
            );
        }
    }

    // a foreign record must survive the merge (regression: PR 6)
    let mut probe = PerfLog::new();
    probe.push(PerfRecord::basic("bench_other", "keepme", "Diagonal", "CSR", 4, 4, 1.0));
    probe.merge_save("BENCH_corpus.json").expect("seed foreign record");
    rep.save("BENCH_corpus.json").expect("write BENCH_corpus.json");
    let merged = PerfLog::parse(
        &std::fs::read_to_string("BENCH_corpus.json").expect("read artifact"),
    )
    .expect("parse artifact");
    assert!(
        merged.records.iter().any(|r| r.bench == "bench_other" && r.matrix == "keepme"),
        "merge_save must preserve other benches' records"
    );
    assert_eq!(
        merged.records.iter().filter(|r| r.bench == "bench_corpus").count(),
        rep.rows.len()
    );
    println!("wrote BENCH_corpus.json ({} corpus records)", rep.rows.len());
}
