//! Bench: the Table II application workloads end to end — GCN forward
//! pass, block power iteration, and batched PageRank — per SpMM
//! implementation. Reports whole-pipeline wall time, whole-pipeline
//! GFLOP/s (every stage's FLOPs over every stage's time — dividing
//! SpMM-only FLOPs by whole-chain time under-reports throughput), and
//! a per-op time breakdown so the paper's "SpMM is the bottleneck of
//! these apps" framing is visible in context.

use spmm_roofline::config::ExperimentConfig;
use spmm_roofline::coordinator::{BufferPool, PipelineKind};
use spmm_roofline::gen::{chung_lu, erdos_renyi, mesh2d, ChungLuParams, MeshKind, Prng};
use spmm_roofline::metrics::{gflops, spmm_flops, Timer};
use spmm_roofline::report::{PerfLog, PerfRecord};
use spmm_roofline::spmm::{build_native, pool, DenseMatrix, Impl};
use spmm_roofline::workloads::{
    gcn_chain, gcn_random_inputs, pagerank_chain, power_chain, power_random_input,
    transition_matrix, OpSecs,
};

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// A `bench_workloads` record: workload name doubles as the matrix
/// column, `d` is the workload's dense width (untiled: the workloads
/// drive kernels through the plain `execute` path).
fn wl_record(workload: &str, class: &str, im: Impl, d: usize, gf: f64) -> PerfRecord {
    PerfRecord::basic("bench_workloads", workload, class, im.to_string(), d, d, gf)
}

fn breakdown(per_op: &[OpSecs]) -> String {
    per_op.iter().map(|o| format!("{} {:.1}ms", o.op, o.secs * 1e3)).collect::<Vec<_>>().join(", ")
}

fn main() {
    let scale = envf("REPRO_SCALE", 0.25);
    let cfg = ExperimentConfig { scale, ..Default::default() };
    let mut rng = Prng::new(0x307);
    let mut log = PerfLog::new();

    // GCN: 2-layer forward over a scale-free graph (d = 32 features).
    // Whole-pipeline FLOPs (both SpMMs *and* the dense transforms) over
    // whole-pipeline time — the per-op breakdown shows the split.
    let n = (32768.0 * scale) as usize;
    let g = chung_lu(ChungLuParams { n, alpha: 2.3, avg_deg: 16.0, k_min: 4.0 }, &mut rng);
    let dims = [32usize, 32, 16];
    let (h0, layers) = gcn_random_inputs(n, &dims, 0x307_6c9);
    let gcn_kind = PipelineKind::Gcn { dims: dims.to_vec() };
    let gcn_flops = gcn_kind.pipeline_params(n, g.nnz(), gcn_kind.ops()).flops();
    println!("GCN forward (n={n}, nnz={}, 2 layers, d=32→32→16):", g.nnz());
    for im in [Impl::Csr, Impl::Opt, Impl::Csb] {
        let k = build_native(im, &g, cfg.threads).unwrap();
        let sched = k.plan(None);
        let mut pool = BufferPool::new();
        let t = Timer::start();
        let (out, per_op) = gcn_chain(k.as_ref(), &sched, &h0, &layers, &mut pool).unwrap();
        let dt = t.elapsed_secs();
        let gf = gflops(gcn_flops, dt);
        println!(
            "  {im}: {:.1} ms  ({gf:.2} GFLOP/s whole-chain; {}; |out|={:.3})",
            dt * 1e3,
            breakdown(&per_op),
            out.frob_norm()
        );
        log.push(wl_record("gcn_forward", "ScaleFree", im, 32, gf));
    }

    // Block power iteration over an FE-mesh proxy (d = 8 vectors)
    let mesh = mesh2d((360.0 * scale.sqrt()) as usize, MeshKind::Triangular, 1.0, &mut rng);
    let x0 = power_random_input(mesh.nrows, 8, 0x307_6ca);
    let pw_kind = PipelineKind::PowerIteration { d: 8, iters: 20 };
    let pw_flops = pw_kind.pipeline_params(mesh.nrows, mesh.nnz(), 20).flops();
    println!("\nBlock power iteration (mesh n={}, nnz={}, d=8, 20 iters):", mesh.nrows, mesh.nnz());
    for im in [Impl::Csr, Impl::Opt, Impl::Csb, Impl::Bsr] {
        let k = build_native(im, &mesh, cfg.threads).unwrap();
        let sched = k.plan(None);
        let mut pool = BufferPool::new();
        let t = Timer::start();
        let (_, stats, per_op) = power_chain(k.as_ref(), &sched, &x0, 20, &mut pool).unwrap();
        let dt = t.elapsed_secs();
        let gf = gflops(pw_flops, dt);
        println!(
            "  {im}: {:.1} ms  ({gf:.2} GFLOP/s whole-chain; {}; λ̂={:.3}, resid={:.1e})",
            dt * 1e3,
            breakdown(&per_op),
            stats.lambda_max,
            stats.residual
        );
        log.push(wl_record("block_power", "Blocked", im, 8, gf));
    }

    // Per-call dispatch overhead: thousands of tiny SpMMs. This is the
    // regime the persistent worker pool exists for — with spawn-per-call
    // scoped threads (the pre-pool implementation), OS thread churn
    // dominated these calls; with parked workers the per-call cost is a
    // condvar wake. Tiny matrix → the kernel itself is microseconds, so
    // the printed µs/call is almost pure dispatch overhead.
    let tiny = erdos_renyi(256, 256, 4.0, &mut rng);
    let bt = DenseMatrix::random(256, 8, &mut rng);
    let mut ct = DenseMatrix::zeros(256, 8);
    const CALLS: usize = 2000;
    println!(
        "\nPer-call dispatch overhead (n=256, nnz={}, d=8, {CALLS} calls, pool: {} workers):",
        tiny.nnz(),
        pool::global().workers()
    );
    for im in [Impl::Csr, Impl::Opt, Impl::Csb] {
        let k = build_native(im, &tiny, cfg.threads).unwrap();
        k.execute(&bt, &mut ct).unwrap(); // warm the pool + caches
        let t = Timer::start();
        for _ in 0..CALLS {
            k.execute(&bt, &mut ct).unwrap();
        }
        let dt = t.elapsed_secs();
        let gf = gflops(CALLS as f64 * spmm_flops(tiny.nnz(), 8), dt);
        println!(
            "  {im}: {:.1} ms total, {:.2} µs/call  ({:.2} GFLOP/s sustained)",
            dt * 1e3,
            dt / CALLS as f64 * 1e6,
            gf
        );
        log.push(wl_record("dispatch_tiny", "Random", im, 8, gf));
    }

    // Batched PageRank on the scale-free graph (8 seeds). The
    // transition operator is built once outside the timed region (it
    // is amortized across implementations in practice); the timed
    // chain charges the SpMM sweeps *and* the rank-update passes at
    // the executed iteration count.
    let seeds = [1usize, 2, 3, 4, 5, 6, 7, 8];
    let (m, dangling) = transition_matrix(&g).unwrap();
    println!("\nBatched PageRank (n={n}, 8 personalization vectors):");
    for im in [Impl::Csr, Impl::Opt] {
        let k = build_native(im, &m, cfg.threads).unwrap();
        let sched = k.plan(None);
        let mut pool = BufferPool::new();
        let t = Timer::start();
        let (r, per_op) =
            pagerank_chain(k.as_ref(), &sched, &dangling, &seeds, 0.85, 1e-8, 100, &mut pool)
                .unwrap();
        let dt = t.elapsed_secs();
        let pr_kind = PipelineKind::PageRank {
            seeds: seeds.to_vec(),
            alpha: 0.85,
            tol: 1e-8,
            iters: r.iterations,
        };
        let gf = gflops(pr_kind.pipeline_params(n, m.nnz(), r.iterations).flops(), dt);
        println!(
            "  {im}: {:.1} ms  ({} iters, {gf:.2} GFLOP/s whole-chain; {}; δ={:.1e})",
            dt * 1e3,
            r.iterations,
            breakdown(&per_op),
            r.delta
        );
        log.push(wl_record("batched_pagerank", "ScaleFree", im, 8, gf));
    }

    log.merge_save("BENCH_schedule.json").expect("write BENCH_schedule.json");
    println!("\nwrote BENCH_schedule.json ({} bench_workloads records)", log.records.len());
}
