//! Bench: the Table II application workloads end to end — GCN forward
//! pass, block power iteration, and batched PageRank — per SpMM
//! implementation. Reports wall time and effective SpMM GFLOP/s so
//! the paper's "SpMM is the bottleneck of these apps" framing is
//! visible in context.

use spmm_roofline::config::ExperimentConfig;
use spmm_roofline::gen::{chung_lu, erdos_renyi, mesh2d, ChungLuParams, MeshKind, Prng};
use spmm_roofline::metrics::{gflops, spmm_flops, Timer};
use spmm_roofline::report::{PerfLog, PerfRecord};
use spmm_roofline::spmm::{build_native, pool, DenseMatrix, Impl};
use spmm_roofline::workloads::{batched_pagerank, block_power_iteration, gcn_forward, GcnLayer};

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// A `bench_workloads` record: workload name doubles as the matrix
/// column, `d` is the workload's dense width (untiled: the workloads
/// drive kernels through the plain `execute` path).
fn wl_record(workload: &str, class: &str, im: Impl, d: usize, gf: f64) -> PerfRecord {
    PerfRecord::basic("bench_workloads", workload, class, im.to_string(), d, d, gf)
}

fn main() {
    let scale = envf("REPRO_SCALE", 0.25);
    let cfg = ExperimentConfig { scale, ..Default::default() };
    let mut rng = Prng::new(0x307);
    let mut log = PerfLog::new();

    // GCN: 2-layer forward over a scale-free graph (d = 32 features)
    let n = (32768.0 * scale) as usize;
    let g = chung_lu(ChungLuParams { n, alpha: 2.3, avg_deg: 16.0, k_min: 4.0 }, &mut rng);
    let h0 = DenseMatrix::random(n, 32, &mut rng);
    let layers =
        vec![GcnLayer::new(DenseMatrix::random(32, 32, &mut rng)),
             GcnLayer::new(DenseMatrix::random(32, 16, &mut rng))];
    println!("GCN forward (n={n}, nnz={}, 2 layers, d=32→32→16):", g.nnz());
    for im in [Impl::Csr, Impl::Opt, Impl::Csb] {
        let k = build_native(im, &g, cfg.threads).unwrap();
        let t = Timer::start();
        let out = gcn_forward(k.as_ref(), &h0, &layers).unwrap();
        let dt = t.elapsed_secs();
        let spmm_part = spmm_flops(g.nnz(), 32) + spmm_flops(g.nnz(), 32);
        println!(
            "  {im}: {:.1} ms  (SpMM portion ≈ {:.2} GFLOP/s, |out|={:.3})",
            dt * 1e3,
            gflops(spmm_part, dt),
            out.frob_norm()
        );
        log.push(wl_record("gcn_forward", "ScaleFree", im, 32, gflops(spmm_part, dt)));
    }

    // Block power iteration over an FE-mesh proxy (d = 8 vectors)
    let mesh = mesh2d((360.0 * scale.sqrt()) as usize, MeshKind::Triangular, 1.0, &mut rng);
    let x0 = DenseMatrix::random(mesh.nrows, 8, &mut rng);
    println!("\nBlock power iteration (mesh n={}, nnz={}, d=8, 20 iters):", mesh.nrows, mesh.nnz());
    for im in [Impl::Csr, Impl::Opt, Impl::Csb, Impl::Bsr] {
        let k = build_native(im, &mesh, cfg.threads).unwrap();
        let t = Timer::start();
        let (_, stats) = block_power_iteration(k.as_ref(), &x0, 20).unwrap();
        let dt = t.elapsed_secs();
        let gf = gflops(20.0 * spmm_flops(mesh.nnz(), 8), dt);
        println!(
            "  {im}: {:.1} ms  ({:.2} GFLOP/s, λ̂={:.3}, resid={:.1e})",
            dt * 1e3,
            gf,
            stats.lambda_max,
            stats.residual
        );
        log.push(wl_record("block_power", "Blocked", im, 8, gf));
    }

    // Per-call dispatch overhead: thousands of tiny SpMMs. This is the
    // regime the persistent worker pool exists for — with spawn-per-call
    // scoped threads (the pre-pool implementation), OS thread churn
    // dominated these calls; with parked workers the per-call cost is a
    // condvar wake. Tiny matrix → the kernel itself is microseconds, so
    // the printed µs/call is almost pure dispatch overhead.
    let tiny = erdos_renyi(256, 256, 4.0, &mut rng);
    let bt = DenseMatrix::random(256, 8, &mut rng);
    let mut ct = DenseMatrix::zeros(256, 8);
    const CALLS: usize = 2000;
    println!(
        "\nPer-call dispatch overhead (n=256, nnz={}, d=8, {CALLS} calls, pool: {} workers):",
        tiny.nnz(),
        pool::global().workers()
    );
    for im in [Impl::Csr, Impl::Opt, Impl::Csb] {
        let k = build_native(im, &tiny, cfg.threads).unwrap();
        k.execute(&bt, &mut ct).unwrap(); // warm the pool + caches
        let t = Timer::start();
        for _ in 0..CALLS {
            k.execute(&bt, &mut ct).unwrap();
        }
        let dt = t.elapsed_secs();
        let gf = gflops(CALLS as f64 * spmm_flops(tiny.nnz(), 8), dt);
        println!(
            "  {im}: {:.1} ms total, {:.2} µs/call  ({:.2} GFLOP/s sustained)",
            dt * 1e3,
            dt / CALLS as f64 * 1e6,
            gf
        );
        log.push(wl_record("dispatch_tiny", "Random", im, 8, gf));
    }

    // Batched PageRank on the scale-free graph (8 seeds)
    println!("\nBatched PageRank (n={n}, 8 personalization vectors):");
    for im in [Impl::Csr, Impl::Opt] {
        let t = Timer::start();
        let r = batched_pagerank(&g, &[1, 2, 3, 4, 5, 6, 7, 8], 0.85, 1e-8, 100, im, cfg.threads)
            .unwrap();
        let dt = t.elapsed_secs();
        let gf = gflops(r.iterations as f64 * spmm_flops(g.nnz(), 8), dt);
        println!(
            "  {im}: {:.1} ms  ({} iters, {:.2} GFLOP/s, δ={:.1e})",
            dt * 1e3,
            r.iterations,
            gf,
            r.delta
        );
        log.push(wl_record("batched_pagerank", "ScaleFree", im, 8, gf));
    }

    log.merge_save("BENCH_schedule.json").expect("write BENCH_schedule.json");
    println!("\nwrote BENCH_schedule.json ({} bench_workloads records)", log.records.len());
}
