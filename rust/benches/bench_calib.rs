//! Bench: the measured calibration ladder end to end.
//!
//! Runs the per-cache-level read/write/triad bandwidth sweep plus the
//! width-aware FMA peak probe (`membench::calibrate_with`), prints the
//! resulting `MeasuredLadder`, then proves the restart contract: the
//! ladder is persisted through an `AutotuneState` snapshot and a
//! second engine restoring that snapshot reports a *measured* planner
//! ladder without running any sweep of its own.
//!
//! `REPRO_SCALE` (default 0.25) scales the sweep cap and peak iters;
//! `REPRO_ITERS` (default 3) sets the reps per point; `REPRO_FAST=1`
//! injects nominal machine parameters for the engines (no STREAM run —
//! CI smoke mode). Writes one `BENCH_calib.json` record per rung plus
//! a peak record and asserts every ladder level name landed in the
//! artifact.

use spmm_roofline::coordinator::{AutotunePolicy, Engine, EngineConfig, LadderSource};
use spmm_roofline::membench::{calibrate_with, CalibConfig};
use spmm_roofline::model::MachineParams;
use spmm_roofline::report::{PerfLog, PerfRecord};
use spmm_roofline::spmm::Impl;

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env1(key: &str) -> bool {
    std::env::var(key).map(|v| v == "1").unwrap_or(false)
}

fn main() {
    let scale = envf("REPRO_SCALE", 0.25).max(0.001);
    let reps = (envf("REPRO_ITERS", 3.0) as usize).max(1);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let ccfg = CalibConfig {
        reps,
        max_len: (((64usize << 20) as f64 * scale) as usize).max(1 << 12),
        peak_iters: ((4_000_000f64 * scale) as usize).max(10_000),
    };
    println!(
        "calibrating: {threads} threads, {reps} reps, sweep cap {} doubles, peak iters {}",
        ccfg.max_len, ccfg.peak_iters
    );
    let ml = calibrate_with(threads, ccfg);
    for l in &ml.levels {
        println!(
            "  {:>5}: read {:.2}  write {:.2}  triad {:.2} GB/s",
            l.level, l.read_gbs, l.write_gbs, l.triad_gbs
        );
    }
    println!("  peak {:.2} GFLOP/s (simd {})", ml.peak_gflops, ml.simd_level);

    // — restart contract: persist → restore → planner prefers measured —
    let machine = if env1("REPRO_FAST") {
        Some(MachineParams { beta_gbs: 25.0, pi_gflops: 100.0 })
    } else {
        None
    };
    let cfg = EngineConfig {
        threads,
        machine,
        iters: 1,
        warmup: 0,
        impls: vec![Impl::Csr],
        artifacts_dir: None,
        autotune: AutotunePolicy::default(),
    };
    let path = std::env::temp_dir().join(format!("bench_calib_state_{}.json", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    let mut e1 = Engine::new(cfg.clone()).expect("engine construction");
    e1.install_measured_ladder(ml.clone());
    e1.save_state(&path).expect("persist snapshot");
    let mut e2 = Engine::new(cfg).expect("engine construction");
    assert_eq!(e2.planner().ladder_source(), LadderSource::Nominal);
    assert!(e2.load_state(&path), "healthy snapshot must load");
    assert_eq!(
        e2.planner().ladder_source(),
        LadderSource::Measured,
        "restored engine must prefer the measured ladder"
    );
    assert_eq!(e2.measured_ladder(), Some(&ml), "ladder must survive the snapshot round trip");
    let _ = std::fs::remove_file(&path);
    println!("restart contract: restored engine prefers the measured ladder, zero re-measurement");

    // — artifact: one record per rung (measured β) plus the peak probe —
    let mut log = PerfLog::new();
    for l in &ml.levels {
        log.push(PerfRecord {
            predicted_gflops: l.triad_gbs,
            ..PerfRecord::basic(
                "bench_calib",
                l.level.clone(),
                "calib",
                ml.simd_level.clone(),
                ml.threads,
                0,
                l.beta_gbs(),
            )
        });
    }
    log.push(PerfRecord {
        predicted_gflops: ml.peak_gflops,
        ..PerfRecord::basic(
            "bench_calib",
            "peak",
            "calib",
            ml.simd_level.clone(),
            ml.threads,
            0,
            ml.peak_gflops,
        )
    });
    log.merge_save("BENCH_calib.json").expect("write BENCH_calib.json");
    let text = std::fs::read_to_string("BENCH_calib.json").expect("read artifact back");
    for l in &ml.levels {
        assert!(
            text.contains(&format!("\"{}\"", l.level)),
            "BENCH_calib.json is missing ladder level {}",
            l.level
        );
    }
    assert!(text.contains("\"peak\""), "BENCH_calib.json is missing the peak record");
    println!(
        "wrote BENCH_calib.json ({} rung records + peak, all levels present)",
        ml.levels.len()
    );
}
