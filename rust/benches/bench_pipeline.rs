//! Bench: pipeline-first workloads through the engine — whole chains
//! (GCN forward, block power iteration, batched PageRank, SpGEMM→SpMM)
//! tuned end-to-end against the inter-op roofline, then served from
//! the pinned whole-chain plan.
//!
//! Writes `BENCH_pipeline.json`: one whole-chain record per (matrix,
//! chain) with predicted vs measured GFLOP/s, plus per-op records
//! (`class = "per_op"`, impl column = op label) splitting the chain's
//! throughput between the SpMM sweeps and the non-SpMM stages. CI
//! greps for both shapes.
//!
//! Also asserts the tentpole invariants in-process: a pinned
//! re-submission explores nothing, and the pinned plans survive a
//! JSON state round-trip into a fresh engine that then serves with
//! zero measurements.
//!
//! `REPRO_SCALE` (default 0.25) and `REPRO_ITERS` (default 2) tune
//! load; `REPRO_FAST=1` injects nominal machine parameters to skip
//! STREAM/FMA calibration.

use spmm_roofline::coordinator::{
    AutotunePolicy, Engine, EngineConfig, PipelineKind, PipelineRecord, PipelineSpec,
};
use spmm_roofline::gen::representative_suite;
use spmm_roofline::model::MachineParams;
use spmm_roofline::report::{AutotuneState, PerfLog, PerfRecord};
use spmm_roofline::spmm::Impl;

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn build_engine(scale: f64, iters: usize, machine: Option<MachineParams>) -> Engine {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut engine = Engine::new(EngineConfig {
        threads,
        machine,
        iters,
        warmup: 1,
        impls: vec![Impl::Csr, Impl::Opt, Impl::Csb],
        artifacts_dir: None,
        autotune: AutotunePolicy::enabled(),
    })
    .expect("engine construction");
    for proxy in representative_suite() {
        engine.register(proxy.name, proxy.generate(scale)).expect("register");
    }
    engine
}

/// Whole-chain + per-op records for one executed pipeline. The per-op
/// split charges the SpMM sweeps with the chain's SpMM FLOPs and the
/// non-SpMM stage with the model's `extra_flops`; ops whose FLOPs the
/// record does not carry (the data-dependent SpGEMM leg) log the time
/// split with zero throughput.
fn push_records(
    log: &mut PerfLog,
    rec: &PipelineRecord,
    kind: &PipelineKind,
    pp_flops: (f64, f64),
) {
    let cell = format!("{}|{}", rec.matrix, rec.chain);
    log.push(PerfRecord {
        reorder: rec.reorder.to_string(),
        predicted_gflops: rec.predicted_gflops,
        ..PerfRecord::basic(
            "bench_pipeline",
            cell.clone(),
            rec.class.to_string(),
            rec.chosen.to_string(),
            kind.d(),
            rec.dt,
            rec.measured_gflops,
        )
    });
    let (spmm_flops, extra_flops) = pp_flops;
    for op in &rec.per_op {
        let gf = if op.secs <= 0.0 {
            0.0
        } else if op.op == "spmm" {
            spmm_flops / op.secs / 1e9
        } else if extra_flops > 0.0 {
            extra_flops / op.secs / 1e9
        } else {
            0.0
        };
        log.push(PerfRecord::basic(
            "bench_pipeline",
            cell.clone(),
            "per_op",
            op.op,
            kind.d(),
            rec.dt,
            gf,
        ));
    }
}

fn main() {
    let scale = envf("REPRO_SCALE", 0.25);
    let iters = envf("REPRO_ITERS", 2.0) as usize;
    let fast = std::env::var("REPRO_FAST").map(|v| v == "1").unwrap_or(false);
    let machine =
        if fast { Some(MachineParams { beta_gbs: 25.0, pi_gflops: 100.0 }) } else { None };

    let mut engine = build_engine(scale, iters, machine);
    println!(
        "pipeline bench: β={:.1} GB/s π={:.0} GFLOP/s",
        engine.machine().beta_gbs,
        engine.machine().pi_gflops
    );

    let d = 16usize;
    let names: Vec<String> =
        engine.registry().names().iter().map(|s| s.to_string()).collect();
    let mut specs: Vec<PipelineSpec> = Vec::new();
    for name in &names {
        specs.push(PipelineSpec::new(name.clone(), PipelineKind::Gcn { dims: vec![d, d, d / 2] }));
        specs.push(PipelineSpec::new(name.clone(), PipelineKind::PowerIteration { d, iters: 8 }));
        specs.push(PipelineSpec::new(
            name.clone(),
            PipelineKind::PageRank { seeds: (0..4).collect(), alpha: 0.85, tol: 1e-9, iters: 10 },
        ));
    }
    if let Some(first) = names.first() {
        let kind = PipelineKind::SpGemmSpMM { b: first.clone(), d };
        specs.push(PipelineSpec::new(first.clone(), kind));
    }

    let mut log = PerfLog::new();
    println!("— tuning pass ({} chains, measured end-to-end per candidate) —", specs.len());
    for spec in &specs {
        let rec = engine.submit_pipeline(spec).expect("pipeline");
        let entry = engine.registry().get(&spec.matrix).expect("registered");
        let pp = spec.kind.pipeline_params(entry.n(), entry.nnz(), rec.ops.max(1));
        push_records(&mut log, &rec, &spec.kind, (pp.flops() - pp.extra_flops, pp.extra_flops));
        let ops: Vec<String> =
            rec.per_op.iter().map(|o| format!("{} {:.1}ms", o.op, o.secs * 1e3)).collect();
        println!(
            "  {}  {}  {} pred {:.2} meas {:.2} GF/s  [{}]",
            rec.matrix,
            rec.chain,
            rec.chosen,
            rec.predicted_gflops,
            rec.measured_gflops,
            ops.join(", ")
        );
    }

    // pinned re-submission must not measure anything new
    let before = engine.autotuner().measurements();
    for spec in &specs {
        engine.submit_pipeline(spec).expect("pinned pipeline");
    }
    let explored = engine.autotuner().measurements() - before;
    assert_eq!(explored, 0, "pinned re-submission explored {explored} candidates");
    println!("pinned re-submission: 0 new measurements across {} chains", specs.len());

    // pinned plans survive a JSON state round-trip into a fresh engine
    // that then serves without exploring at all
    let state = engine.export_state();
    assert!(!state.pipelines.is_empty(), "tuning produced no pinned pipeline plans");
    let restored = AutotuneState::parse(&state.to_json()).expect("state round-trip");
    let mut fresh = build_engine(scale, iters, Some(engine.machine()));
    let adopted = fresh.restore_state(&restored);
    assert!(adopted > 0, "fresh engine adopted no pinned decisions");
    for spec in &specs {
        fresh.submit_pipeline(spec).expect("restored pipeline");
    }
    assert_eq!(
        fresh.autotuner().measurements(),
        0,
        "restored engine explored despite pinned chain plans"
    );
    println!(
        "state round-trip: {} pinned chain plans restored, 0 measurements on re-serve",
        state.pipelines.len()
    );

    log.merge_save("BENCH_pipeline.json").expect("write BENCH_pipeline.json");
    println!("wrote BENCH_pipeline.json ({} bench_pipeline records)", log.records.len());
}
