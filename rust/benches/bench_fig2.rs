//! Bench: regenerate the paper's Fig. 2 (sparsity-aware roofline
//! overlays: bandwidth roof, model-AI verticals, measured points).
//!
//! β and π are measured on this machine (STREAM + FMA loop) before the
//! sweep. Writes `results/fig2_*.svg` + `results/fig2.csv`.

use spmm_roofline::config::ExperimentConfig;
use spmm_roofline::harness::{machine_params_cached, run_fig2};

fn envf(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let cfg = ExperimentConfig {
        scale: envf("REPRO_SCALE", 0.25),
        iters: envf("REPRO_ITERS", 3.0) as usize,
        warmup: 1,
        ..Default::default()
    };
    let machine = machine_params_cached(cfg.threads);
    eprintln!(
        "bench_fig2: scale={} β={:.1} GB/s π={:.0} GFLOP/s (paper: β=122.6)",
        cfg.scale, machine.beta_gbs, machine.pi_gflops
    );
    let data = run_fig2(&cfg, Some(machine)).expect("fig2 sweep failed");
    println!("{}", data.render().to_text());
    println!("shape checks vs the paper's §IV-D claims:");
    for (desc, ok) in data.shape_checks() {
        println!("  [{}] {desc}", if ok { "PASS" } else { "FAIL" });
    }
    data.save_svgs("results").expect("svg write failed");
    data.save_csv("results/fig2.csv").expect("csv write failed");
    println!("wrote results/fig2_*.svg and results/fig2.csv");
}
