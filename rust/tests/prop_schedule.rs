//! Property tests over the schedule layer: partition coverage under
//! adversarial row distributions, and kernel equivalence at forced
//! column-tile widths.

use spmm_roofline::coordinator::{Engine, EngineConfig, JobSpec};
use spmm_roofline::gen::{erdos_renyi, Prng};
use spmm_roofline::model::MachineParams;
use spmm_roofline::sparse::{Coo, Csr};
use spmm_roofline::spmm::{build_native, reference_spmm, DenseMatrix, Impl, Schedule};
use spmm_roofline::testutil::check_default;

/// Coverage invariant: partitions are contiguous, ordered, and cover
/// `[0, units)` exactly once.
fn assert_covers(s: &Schedule, units: usize) -> Result<(), String> {
    if s.units() != units {
        return Err(format!("schedule covers {} units, want {units}", s.units()));
    }
    let mut expect = 0;
    for i in 0..s.n_parts() {
        let r = s.part(i);
        if r.start != expect {
            return Err(format!("part {i} starts at {} but {expect} uncovered", r.start));
        }
        if r.end < r.start {
            return Err(format!("part {i} is inverted: {r:?}"));
        }
        expect = r.end;
    }
    if expect != units {
        return Err(format!("partitions end at {expect}, want {units}"));
    }
    Ok(())
}

/// An adversarial CSR: `n` rows where a fraction are empty and one hub
/// row holds ~90% of the nnz.
fn hub_matrix(n: usize, rng: &mut Prng) -> Csr {
    let hub = rng.below_usize(n);
    let mut rows = Vec::new();
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    // hub row: 9 entries per light row's 1, spread over the columns
    for c in 0..(9 * n / 10).max(1).min(n) {
        rows.push(hub as u32);
        cols.push(c as u32);
        vals.push(1.0 + c as f64);
    }
    for r in 0..n {
        if r == hub || rng.below_usize(3) == 0 {
            continue; // empty row
        }
        rows.push(r as u32);
        cols.push(rng.below_usize(n) as u32);
        vals.push(-(r as f64) - 1.0);
    }
    Csr::from_coo(Coo { nrows: n, ncols: n, rows, cols, vals })
}

#[test]
fn prop_nnz_partitions_cover_adversarial_prefixes() {
    check_default(0x300, |rng| {
        let units = 1 + rng.below_usize(300);
        let threads = 1 + rng.below_usize(8);
        // random prefix with empty rows and occasional huge rows
        let mut prefix = vec![0usize; units + 1];
        for i in 0..units {
            let w = match rng.below_usize(10) {
                0 => 0,                          // empty row
                1 => 1000 + rng.below_usize(9000), // hub row
                _ => rng.below_usize(8),
            };
            prefix[i + 1] = prefix[i] + w;
        }
        let s = Schedule::nnz_balanced(&prefix, threads);
        assert_covers(&s, units)
    });
}

#[test]
fn prop_hub_matrix_partitions_cover_and_kernels_agree() {
    check_default(0x301, |rng| {
        let n = 20 + rng.below_usize(200);
        let a = hub_matrix(n, rng);
        let threads = 1 + rng.below_usize(4);
        let d = 1 + rng.below_usize(12);
        let b = DenseMatrix::random(n, d, rng);
        let want = reference_spmm(&a, &b);
        for im in Impl::NATIVE {
            let k = build_native(im, &a, threads).map_err(|e| e.to_string())?;
            let s = k.plan(None);
            assert_covers(&s, s.units())?;
            let mut c = DenseMatrix::zeros(n, d);
            k.execute_with(&b, &mut c, &s).map_err(|e| e.to_string())?;
            let diff = c.max_abs_diff(&want);
            if diff > 1e-11 {
                return Err(format!("{im} hub matrix (threads={threads}, d={d}): |Δ|={diff}"));
            }
        }
        Ok(())
    });
}

#[test]
fn forced_tile_widths_match_reference_for_all_kernels() {
    // the acceptance grid: dt ∈ {1, 3, d-1, d} for every native kernel
    let mut rng = Prng::new(0x302);
    let a = erdos_renyi(180, 180, 6.0, &mut rng);
    for d in [2usize, 7, 16, 64] {
        let b = DenseMatrix::random(180, d, &mut rng);
        let want = reference_spmm(&a, &b);
        for im in Impl::NATIVE {
            let k = build_native(im, &a, 3).unwrap();
            for dt in [1, 3, d - 1, d] {
                let s = k.plan(Some(dt));
                // stale C: tiled execution must still fully overwrite
                let mut c = DenseMatrix::from_vec(180, d, vec![99.0; 180 * d]);
                k.execute_with(&b, &mut c, &s).unwrap();
                let diff = c.max_abs_diff(&want);
                assert!(diff < 1e-11, "{im} d={d} dt={dt}: |Δ|={diff}");
            }
        }
    }
}

#[test]
fn prop_random_tiles_match_reference() {
    check_default(0x303, |rng| {
        let n = 8 + rng.below_usize(120);
        let a = erdos_renyi(n, n, rng.range_f64(0.0, 8.0), rng);
        let d = 1 + rng.below_usize(20);
        let dt = 1 + rng.below_usize(d + 4); // sometimes > d (untiled)
        let threads = 1 + rng.below_usize(3);
        let b = DenseMatrix::random(n, d, rng);
        let want = reference_spmm(&a, &b);
        for im in Impl::NATIVE {
            let k = build_native(im, &a, threads).map_err(|e| e.to_string())?;
            let mut c = DenseMatrix::zeros(n, d);
            k.execute_with(&b, &mut c, &k.plan(Some(dt))).map_err(|e| e.to_string())?;
            let diff = c.max_abs_diff(&want);
            if diff > 1e-11 {
                return Err(format!("{im} (n={n}, d={d}, dt={dt}): |Δ|={diff}"));
            }
        }
        Ok(())
    });
}

#[test]
fn schedule_cache_reuses_across_repeated_and_batched_submissions() {
    let mut e = Engine::new(EngineConfig {
        threads: 2,
        machine: Some(MachineParams { beta_gbs: 10.0, pi_gflops: 100.0 }),
        iters: 1,
        warmup: 0,
        impls: vec![Impl::Csr, Impl::Opt, Impl::Csb],
        artifacts_dir: None,
        ..EngineConfig::default()
    })
    .unwrap();
    let a = erdos_renyi(400, 400, 5.0, &mut Prng::new(0x304));
    e.register("m", a).unwrap();

    // repeated single submissions: one plan, then cache hits
    e.submit(&JobSpec::new("m", 8).with_impl(Impl::Csr)).unwrap();
    let (h0, m0) = e.registry().schedule_cache_stats();
    assert_eq!((h0, m0), (0, 1));
    for _ in 0..3 {
        e.submit(&JobSpec::new("m", 8).with_impl(Impl::Csr)).unwrap();
    }
    let (h1, m1) = e.registry().schedule_cache_stats();
    assert_eq!((h1, m1), (3, 1), "repeated submissions must reuse the schedule");

    // batched: distinct (impl, d) cells plan once, repeats hit
    let jobs: Vec<JobSpec> = [4usize, 16, 4, 16, 4]
        .iter()
        .map(|&d| JobSpec::new("m", d).with_impl(Impl::Csb))
        .collect();
    let rep = e.submit_batch(&jobs).unwrap();
    assert_eq!(rep.schedule_misses, 2, "two distinct (impl, d) cells");
    assert_eq!(rep.schedule_hits, 3);
    assert!(rep.schedule_hit_rate() > 0.5);

    // every record carries the tile the schedule executed with
    for r in e.history() {
        assert!(r.dt >= 1 && r.dt <= r.d);
    }
}
