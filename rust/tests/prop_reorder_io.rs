//! Round-trip properties for MatrixMarket IO and symmetric
//! reordering: write → read must preserve CSR exactly (general and
//! symmetric files), and `P·A·Pᵀ` must preserve nonzeros, symmetry,
//! and SpMM results against a permuted dense reference — the
//! invariants the adaptive router's conversions lean on.

use spmm_roofline::gen::{chung_lu, erdos_renyi, mesh2d, ChungLuParams, MeshKind, Prng};
use spmm_roofline::sparse::mm_io::{read_coo, write_csr, write_csr_symmetric};
use spmm_roofline::sparse::reorder::{
    degree_sort, permute_symmetric, random_permutation, reverse_cuthill_mckee,
};
use spmm_roofline::sparse::Csr;
use spmm_roofline::spmm::{build_native, reference_spmm, DenseMatrix, Impl};

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("spmm_roofline_prop_reorder_io");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn general_write_read_preserves_csr_exactly() {
    let mut rng = Prng::new(0x10A);
    // ER graphs are not symmetric in general — the general path must
    // not care
    let a = erdos_renyi(120, 90, 4.0, &mut rng);
    let path = tmp("general.mtx");
    write_csr(&path, &a).unwrap();
    let back = Csr::from_coo(read_coo(&path).unwrap());
    // exact: same structure AND bit-identical values ({:.17e} survives
    // the f64 round-trip)
    assert_eq!(a, back);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn symmetric_write_read_preserves_csr_exactly() {
    let mut rng = Prng::new(0x10B);
    let a = mesh2d(12, MeshKind::Triangular, 0.9, &mut rng);
    assert_eq!(a.transpose(), a, "generator must hand us a symmetric mesh");
    let path = tmp("symmetric.mtx");
    write_csr_symmetric(&path, &a).unwrap();
    // the file stores only the lower triangle
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.starts_with("%%MatrixMarket matrix coordinate real symmetric"));
    let back = Csr::from_coo(read_coo(&path).unwrap());
    assert_eq!(a, back);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn symmetric_writer_rejects_asymmetric_input() {
    let mut rng = Prng::new(0x10C);
    let a = erdos_renyi(50, 50, 3.0, &mut rng);
    if a.transpose() == a {
        return; // astronomically unlikely; nothing to assert then
    }
    assert!(write_csr_symmetric(tmp("bad.mtx"), &a).is_err());
    // rectangular input is rejected outright
    let r = erdos_renyi(8, 10, 2.0, &mut rng);
    assert!(write_csr_symmetric(tmp("rect.mtx"), &r).is_err());
}

/// `expected[perm[r]][k] = Σ_j A[r][j] · B[perm[j]][k]` — the permuted
/// dense reference for `C = (P·A·Pᵀ)·B`.
fn permuted_dense_spmm(a: &Csr, perm: &[u32], b: &DenseMatrix) -> DenseMatrix {
    let (n, d) = (a.nrows, b.ncols);
    let ad = a.to_dense();
    let mut c = DenseMatrix::zeros(n, d);
    for r in 0..n {
        for j in 0..n {
            let v = ad[r * n + j];
            if v == 0.0 {
                continue;
            }
            for k in 0..d {
                let add = v * b.get(perm[j] as usize, k);
                let cur = c.get(perm[r] as usize, k);
                c.set(perm[r] as usize, k, cur + add);
            }
        }
    }
    c
}

#[test]
fn permutations_preserve_nnz_symmetry_and_spmm_results() {
    let mut rng = Prng::new(0x10D);
    let cases: Vec<(&str, Csr)> = vec![
        ("mesh", mesh2d(10, MeshKind::Triangular, 0.9, &mut rng)),
        (
            "scalefree",
            chung_lu(ChungLuParams { n: 90, alpha: 2.2, avg_deg: 6.0, k_min: 2.0 }, &mut rng),
        ),
    ];
    for (name, a) in cases {
        let symmetric = a.transpose() == a;
        let perms: Vec<(&str, Vec<u32>)> = vec![
            ("rcm", reverse_cuthill_mckee(&a)),
            ("degree", degree_sort(&a)),
            ("random", random_permutation(a.nrows, &mut rng)),
        ];
        for (pname, perm) in perms {
            let p = permute_symmetric(&a, &perm);
            assert_eq!(p.nnz(), a.nnz(), "{name}/{pname}: nnz must be preserved");
            if symmetric {
                assert_eq!(p.transpose(), p, "{name}/{pname}: symmetry must be preserved");
            }
            // SpMM through the permuted matrix matches the permuted
            // dense reference — first with the serial oracle, then
            // through a real parallel kernel
            let b = DenseMatrix::random(a.nrows, 4, &mut rng);
            let want = permuted_dense_spmm(&a, &perm, &b);
            let got = reference_spmm(&p, &b);
            assert!(
                got.max_abs_diff(&want) < 1e-10,
                "{name}/{pname}: reference SpMM diverged"
            );
            let kernel = build_native(Impl::Csr, &p, 2).unwrap();
            let mut c = DenseMatrix::zeros(a.nrows, 4);
            kernel.execute(&b, &mut c).unwrap();
            assert!(
                c.max_abs_diff(&want) < 1e-10,
                "{name}/{pname}: CSR kernel SpMM diverged"
            );
        }
    }
}
