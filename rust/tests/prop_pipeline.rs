//! Property tests for engine-routed pipelines: whatever the engine's
//! routing, schedule caching, and buffer pooling did, a pipeline
//! submitted through [`Engine::submit_pipeline_collect`] must be
//! **bitwise identical** to the manual per-op composition of the same
//! chain out of the standalone workload functions.
//!
//! Why bitwise equality is achievable (and therefore demanded): the
//! engine runs the *same* chain cores (`crate::workloads::chain`) the
//! free functions wrap, with an untiled `dt = d` schedule — exactly
//! what `kernel.plan(None)` builds — and dense inputs drawn from the
//! shared seeded generators. The comparison forces the impl on both
//! sides, so the property pins the routing layer, not cross-kernel
//! accumulation order.
//!
//! Alongside the differential property: whole-chain pins — a tuned
//! pipeline's re-submission explores nothing — and persistence — the
//! pinned chain plans survive an emit→parse round trip and a fresh
//! engine restored from them serves the same chains with zero
//! exploration measurements.

use spmm_roofline::coordinator::{
    AutotunePolicy, Engine, EngineConfig, PipelineKind, PipelineOutput, PipelineSpec,
};
use spmm_roofline::gen::{
    banded, chung_lu, erdos_renyi, mesh2d, rmat, ChungLuParams, MeshKind, Prng,
};
use spmm_roofline::model::MachineParams;
use spmm_roofline::report::AutotuneState;
use spmm_roofline::sparse::Csr;
use spmm_roofline::spgemm::{build_spgemm, SpGemmImpl};
use spmm_roofline::spmm::{build_native, DenseMatrix, Impl};
use spmm_roofline::testutil::check;
use spmm_roofline::workloads::{
    batched_pagerank, block_power_iteration, gcn_forward, gcn_random_inputs, power_random_input,
};

fn pipeline_engine(threads: usize, autotune: AutotunePolicy) -> Engine {
    Engine::new(EngineConfig {
        threads,
        machine: Some(MachineParams { beta_gbs: 10.0, pi_gflops: 100.0 }),
        iters: 1,
        warmup: 0,
        impls: vec![Impl::Csr, Impl::Opt, Impl::Csb],
        artifacts_dir: None,
        autotune,
    })
    .unwrap()
}

/// Five structurally distinct square generators — one per sparsity
/// regime the suite models (random, banded, FE-mesh, scale-free,
/// power-law RMAT).
fn gen_matrix(g: usize, rng: &mut Prng) -> Csr {
    match g {
        0 => {
            let n = 90 + rng.below_usize(50);
            erdos_renyi(n, n, 4.0, rng)
        }
        1 => banded(90 + rng.below_usize(50), 4, 0.6, rng),
        2 => mesh2d(8 + rng.below_usize(4), MeshKind::Triangular, 0.9, rng),
        3 => chung_lu(
            ChungLuParams { n: 110 + rng.below_usize(60), alpha: 2.3, avg_deg: 6.0, k_min: 2.0 },
            rng,
        ),
        _ => rmat(7, 4.0, 0.45, 0.22, 0.22, rng),
    }
}

fn bits_eq(got: &[f64], want: &[f64], what: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{what}: length {} vs {}", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        if g.to_bits() != w.to_bits() {
            return Err(format!("{what}: [{i}] {g} vs {w} (bitwise)"));
        }
    }
    Ok(())
}

fn err<E: std::fmt::Display>(e: E) -> String {
    e.to_string()
}

/// Tentpole differential: 5 generators × threads {1, 4} × forced
/// native impls, chain kinds cycled over the cross so every generator
/// meets every chain. Engine route == manual composition, bit for bit.
#[test]
fn engine_routed_pipelines_match_manual_composition_bitwise() {
    check(0x919e1, 2, |rng| {
        let mats: Vec<(String, Csr)> =
            (0..5).map(|g| (format!("g{g}"), gen_matrix(g, rng))).collect();
        for &threads in &[1usize, 4] {
            let mut engine = pipeline_engine(threads, AutotunePolicy::default());
            for (name, m) in &mats {
                engine.register(name, m.clone()).map_err(err)?;
            }
            for (gi, (name, m)) in mats.iter().enumerate() {
                for (ii, &im) in [Impl::Csr, Impl::Opt, Impl::Csb].iter().enumerate() {
                    let seed = rng.next_u64();
                    let n = m.nrows;
                    match (gi + ii) % 4 {
                        0 => {
                            let dims = vec![3 + rng.below_usize(4), 5, 3];
                            let spec = PipelineSpec::new(
                                name.clone(),
                                PipelineKind::Gcn { dims: dims.clone() },
                            )
                            .with_impl(im);
                            let (rec, out) =
                                engine.submit_pipeline_collect(&spec, seed).map_err(err)?;
                            if rec.chosen != im {
                                return Err(format!("forced {im} but ran {}", rec.chosen));
                            }
                            let k = build_native(im, m, threads).map_err(err)?;
                            let (h0, layers) = gcn_random_inputs(n, &dims, seed);
                            let want = gcn_forward(k.as_ref(), &h0, &layers).map_err(err)?;
                            bits_eq(out.data(), &want.data, "gcn")?;
                        }
                        1 => {
                            let (d, iters) = (2 + rng.below_usize(4), 3 + rng.below_usize(5));
                            let spec = PipelineSpec::new(
                                name.clone(),
                                PipelineKind::PowerIteration { d, iters },
                            )
                            .with_impl(im);
                            let (_, out) =
                                engine.submit_pipeline_collect(&spec, seed).map_err(err)?;
                            let k = build_native(im, m, threads).map_err(err)?;
                            let x0 = power_random_input(n, d, seed);
                            let (want, stats) =
                                block_power_iteration(k.as_ref(), &x0, iters).map_err(err)?;
                            match out {
                                PipelineOutput::Power { block, lambda_max, residual } => {
                                    bits_eq(&block, &want.data, "power block")?;
                                    bits_eq(
                                        &[lambda_max, residual],
                                        &[stats.lambda_max, stats.residual],
                                        "power stats",
                                    )?;
                                }
                                _ => return Err("power chain must return Power output".into()),
                            }
                        }
                        2 => {
                            let seeds: Vec<usize> =
                                (0..1 + rng.below_usize(3)).map(|_| rng.below_usize(n)).collect();
                            let spec = PipelineSpec::new(
                                name.clone(),
                                PipelineKind::PageRank {
                                    seeds: seeds.clone(),
                                    alpha: 0.85,
                                    tol: 1e-9,
                                    iters: 12,
                                },
                            )
                            .with_impl(im);
                            let (_, out) =
                                engine.submit_pipeline_collect(&spec, seed).map_err(err)?;
                            let want = batched_pagerank(m, &seeds, 0.85, 1e-9, 12, im, threads)
                                .map_err(err)?;
                            match out {
                                PipelineOutput::PageRank { scores, iterations, .. } => {
                                    bits_eq(&scores, &want.scores.data, "pagerank scores")?;
                                    if iterations != want.iterations {
                                        return Err(format!(
                                            "pagerank iters {iterations} vs {}",
                                            want.iterations
                                        ));
                                    }
                                }
                                _ => {
                                    return Err("pagerank chain must return PageRank output".into())
                                }
                            }
                        }
                        _ => {
                            let d = 2 + rng.below_usize(5);
                            let spec = PipelineSpec::new(
                                name.clone(),
                                PipelineKind::SpGemmSpMM { b: name.clone(), d },
                            )
                            .with_impl(im);
                            let (_, out) =
                                engine.submit_pipeline_collect(&spec, seed).map_err(err)?;
                            let gk = build_spgemm(SpGemmImpl::Hash, m, threads);
                            let product = gk.execute(m).map_err(err)?;
                            let k = build_native(im, &product, threads).map_err(err)?;
                            let b =
                                DenseMatrix::random(product.ncols, d, &mut Prng::new(seed));
                            let mut c = DenseMatrix::zeros(product.nrows, d);
                            k.execute(&b, &mut c).map_err(err)?;
                            bits_eq(out.data(), &c.data, "spgemm+spmm")?;
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

fn chain_specs() -> Vec<PipelineSpec> {
    vec![
        PipelineSpec::new("m", PipelineKind::Gcn { dims: vec![5, 4, 3] }),
        PipelineSpec::new("m", PipelineKind::PowerIteration { d: 3, iters: 4 }),
        PipelineSpec::new(
            "m",
            PipelineKind::PageRank { seeds: vec![0, 1], alpha: 0.85, tol: 1e-9, iters: 6 },
        ),
        PipelineSpec::new("m", PipelineKind::SpGemmSpMM { b: "m".into(), d: 4 }),
    ]
}

fn quick() -> AutotunePolicy {
    AutotunePolicy { explore_iters: 1, explore_min_secs: 0.0, ..AutotunePolicy::enabled() }
}

/// Whole-chain pinning: the first submission of each chain explores,
/// every re-submission serves the pin — zero new measurements, and the
/// executed impl is the pinned one.
#[test]
fn tuned_pipelines_pin_and_resubmission_explores_nothing() {
    check(0x919e2, 3, |rng| {
        let m = gen_matrix(rng.below_usize(5), rng);
        let threads = [1usize, 4][rng.below_usize(2)];
        let mut engine = pipeline_engine(threads, quick());
        engine.register("m", m).map_err(err)?;
        let specs = chain_specs();
        for spec in &specs {
            engine.submit_pipeline(spec).map_err(err)?;
        }
        let tuned = engine.autotuner().measurements();
        if tuned == 0 {
            return Err("the tuning pass must measure candidates".into());
        }
        if engine.autotuner().pipeline_decisions().len() != specs.len() {
            return Err(format!(
                "expected {} pinned chains, got {}",
                specs.len(),
                engine.autotuner().pipeline_decisions().len()
            ));
        }
        for spec in &specs {
            let rec = engine.submit_pipeline(spec).map_err(err)?;
            let dec = engine
                .autotuner()
                .pipeline_decision("m", &rec.chain)
                .ok_or_else(|| format!("no pin for chain {}", rec.chain))?;
            if rec.chosen != dec.im {
                return Err(format!("pin says {} but chain ran {}", dec.im, rec.chosen));
            }
        }
        if engine.autotuner().measurements() != tuned {
            return Err(format!(
                "pinned re-submission explored {} extra candidates",
                engine.autotuner().measurements() - tuned
            ));
        }
        Ok(())
    });
}

/// Persistence: pinned chain plans survive emit→parse byte-stably, and
/// a fresh engine restored from the snapshot serves the same chains
/// with **zero** exploration measurements.
#[test]
fn pinned_pipeline_state_round_trips_and_serves_without_exploring() {
    check(0x919e3, 3, |rng| {
        let m = gen_matrix(rng.below_usize(5), rng);
        let specs = chain_specs();

        let mut e1 = pipeline_engine(2, quick());
        e1.register("m", m.clone()).map_err(err)?;
        for spec in &specs {
            e1.submit_pipeline(spec).map_err(err)?;
        }
        let state = e1.export_state();
        if state.pipelines.len() != specs.len() {
            return Err(format!(
                "expected {} persisted chain plans, got {}",
                specs.len(),
                state.pipelines.len()
            ));
        }
        let json = state.to_json();
        let rt = AutotuneState::parse(&json).map_err(err)?;
        if rt.to_json() != json {
            return Err("emit→parse→emit must be byte-stable".into());
        }

        let mut e2 = pipeline_engine(2, quick());
        e2.register("m", m).map_err(err)?;
        if e2.restore_state(&rt) == 0 {
            return Err("restore adopted nothing".into());
        }
        for spec in &specs {
            e2.submit_pipeline(spec).map_err(err)?;
        }
        if e2.autotuner().measurements() != 0 {
            return Err(format!(
                "restored engine explored {} times despite pinned chain plans",
                e2.autotuner().measurements()
            ));
        }
        Ok(())
    });
}
