//! Integration: the sparsity-aware models against generated structure
//! and the cache simulator (the paper's analytical claims, end to
//! end).

use spmm_roofline::cachesim::{trace_csr_spmm, Hierarchy, HierarchyConfig};
use spmm_roofline::gen::{proxy_suite, Prng};
use spmm_roofline::gen::{banded, chung_lu, erdos_renyi, ChungLuParams};
use spmm_roofline::model::{
    ai_blocked, ai_diagonal, ai_random, ai_scalefree, AiParams, MachineParams, Roofline,
};
use spmm_roofline::pattern::classify;
use spmm_roofline::sparse::Csb;

#[test]
fn random_model_is_the_universal_floor() {
    // The paper's §III claim: "random sparsity represents a worst-case
    // scenario, providing a lower bound" — every structured model's AI
    // must be ≥ the random AI, at every density and width. (Cross-
    // structure orderings like diagonal-vs-blocked are NOT universal:
    // Eq. 4 charges 8 B/nnz for A while Eq. 3 charges 12, so at
    // nnz/row ≈ 1 and d = 1 the printed equations cross — see
    // EXPERIMENTS.md §Ablations.)
    for nnz_per_row in [1usize, 10, 76] {
        let n = 1 << 18;
        let p = |d| AiParams::new(n, d, n * nnz_per_row);
        for d in [1usize, 4, 16, 64] {
            let r = ai_random(p(d));
            let di = ai_diagonal(p(d));
            let bl = ai_blocked(p(d), 1024, (n * nnz_per_row / 32).max(1));
            let sf = ai_scalefree(p(d), 2.2, 0.001);
            // equality is reachable: at nnz/row = 1, d = 1 both
            // denominators evaluate to 28 bytes/row
            assert!(di >= r, "d={d} nnz/row={nnz_per_row}: diag {di} < random {r}");
            assert!(bl > r, "d={d} nnz/row={nnz_per_row}: blocked {bl} <= random {r}");
            assert!(sf > r, "d={d} nnz/row={nnz_per_row}: scale-free {sf} <= random {r}");
        }
    }
    // at the paper's operating point (dense-ish rows, d ≥ 4) the
    // diagonal model IS the ceiling
    let p = AiParams::new(1 << 18, 16, (1 << 18) * 10);
    let di = ai_diagonal(p);
    assert!(di > ai_blocked(p, 1024, p.nnz / 32));
    assert!(di > ai_scalefree(p, 2.2, 0.001));
}

#[test]
fn classifier_matches_provenance_on_full_proxy_suite() {
    // every Table III proxy must classify into its intended class
    for proxy in proxy_suite() {
        let m = proxy.generate(0.05);
        let cls = classify(&m);
        assert_eq!(
            cls.class, proxy.class,
            "{} misclassified: {} (expected {}) — {}",
            proxy.name, cls.class, proxy.class, cls.rationale
        );
    }
}

#[test]
fn simulated_traffic_respects_model_ordering() {
    // random >= diagonal traffic in simulation, for matched nnz
    let n = 4096;
    let d = 16;
    let mut rng = Prng::new(0xAB);
    let er = erdos_renyi(n, n, 9.0, &mut rng);
    let diag = banded(n, 4, 1.0, &mut rng);
    // use the tiny hierarchy so B (524 KB here) exceeds the simulated
    // L3 — the paper's "matrices exceed on-chip caches" regime (§IV-A)
    let sim = |a: &spmm_roofline::sparse::Csr| {
        let mut h = Hierarchy::new(HierarchyConfig::tiny());
        trace_csr_spmm(a, d, &mut h);
        h.report().dram_bytes as f64
    };
    let (t_er, t_diag) = (sim(&er), sim(&diag));
    assert!(t_er > 2.0 * t_diag, "er {t_er} vs diag {t_diag}");

    // and the models predict the same direction
    let ai_er = ai_random(AiParams::new(n, d, er.nnz()));
    let ai_di = ai_diagonal(AiParams::new(n, d, diag.nnz()));
    assert!(ai_di > ai_er);
}

#[test]
fn blocked_model_tracks_csb_statistics() {
    // z and D extracted from a real CSB matrix make Eq. 4 land between
    // the random and diagonal bounds
    let mut rng = Prng::new(0xAC);
    let a = erdos_renyi(8192, 8192, 12.0, &mut rng);
    let csb = Csb::from_csr_with_block(&a, 512);
    let p = AiParams::new(a.nrows, 16, a.nnz());
    let ai_b = ai_blocked(p, csb.block_dim, csb.n_nonzero_blocks());
    assert!(ai_b > ai_random(p), "blocked {ai_b} <= random");
    assert!(ai_b < ai_diagonal(p), "blocked {ai_b} >= diagonal");
}

#[test]
fn scalefree_alpha_from_classifier_feeds_model() {
    let mut rng = Prng::new(0xAD);
    let a = chung_lu(
        ChungLuParams { n: 20_000, alpha: 2.25, avg_deg: 14.0, k_min: 2.0 },
        &mut rng,
    );
    let cls = classify(&a);
    let p = AiParams::new(a.nrows, 16, a.nnz());
    let ai = cls.model.ai(p);
    // the fitted-α model must sit between the random floor and the
    // diagonal ceiling
    assert!(ai > ai_random(p) && ai < ai_diagonal(p), "ai={ai}");
}

#[test]
fn roofline_places_spmm_in_memory_bound_region() {
    let machine = MachineParams::PAPER_PERLMUTTER;
    let roofline = Roofline::new(machine);
    // at the paper's largest width, every model AI stays memory-bound
    let p = AiParams::new(1 << 22, 64, 84_000_000);
    for ai in [
        ai_random(p),
        ai_diagonal(p),
        ai_blocked(p, 1024, 84_000_000 / 32),
        ai_scalefree(p, 2.2, 0.001),
    ] {
        assert!(roofline.memory_bound(ai), "AI {ai} not memory bound");
        assert!(roofline.attainable_gflops(ai) < machine.pi_gflops);
    }
}
