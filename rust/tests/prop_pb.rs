//! Property tests over the propagation-blocking kernel: PB must match
//! the dense reference within tolerance AND the CSR kernel **bit for
//! bit** — both kernels accumulate each `C` element in globally
//! column-ascending order, so their floating-point sequences are
//! identical — across every structural generator, forced column-tile
//! widths, thread counts, and adversarial band geometry.

use spmm_roofline::gen::{
    banded, chung_lu, erdos_renyi, mesh2d, rmat, ChungLuParams, MeshKind, Prng,
};
use spmm_roofline::sparse::Csr;
use spmm_roofline::spmm::{CsrSpmm, DenseMatrix, PbSpmm, Schedule, Spmm};
use spmm_roofline::testutil::{check_default, dense_spmm};

/// One matrix per structural regime (plus R-MAT as the second skewed
/// generator), sized for test speed.
fn generator_suite(rng: &mut Prng) -> Vec<(&'static str, Csr)> {
    vec![
        ("banded", banded(180, 6, 0.4, rng)),
        ("blocked", mesh2d(14, MeshKind::Triangular, 0.9, rng)),
        ("er", erdos_renyi(200, 200, 6.0, rng)),
        ("rmat", rmat(8, 6.0, 0.57, 0.19, 0.19, rng)),
        (
            "scalefree",
            chung_lu(ChungLuParams { n: 250, alpha: 2.2, avg_deg: 8.0, k_min: 2.0 }, rng),
        ),
    ]
}

/// The acceptance grid: every generator × dt ∈ {1, 3, d−1, d} ×
/// threads ∈ {1, 4}, PB vs dense reference and vs CSR bit for bit.
#[test]
fn pb_matches_reference_and_csr_bitwise_across_generators() {
    let mut rng = Prng::new(0x9b0);
    for (name, a) in generator_suite(&mut rng) {
        for d in [3usize, 8, 16] {
            let b = DenseMatrix::random(a.ncols, d, &mut rng);
            let want = dense_spmm(&a, &b);
            for threads in [1usize, 4] {
                let csr = CsrSpmm::new(a.clone(), threads);
                let pb = PbSpmm::from_csr(&a, threads);
                for dt in [1usize, 3, d - 1, d] {
                    let s_csr = csr.plan(Some(dt));
                    let s_pb = pb.plan(Some(dt));
                    // stale C: execution must fully overwrite
                    let mut c_csr =
                        DenseMatrix::from_vec(a.nrows, d, vec![13.0; a.nrows * d]);
                    let mut c_pb =
                        DenseMatrix::from_vec(a.nrows, d, vec![-7.0; a.nrows * d]);
                    csr.execute_with(&b, &mut c_csr, &s_csr).unwrap();
                    pb.execute_with(&b, &mut c_pb, &s_pb).unwrap();
                    let diff = c_pb.max_abs_diff(&want);
                    assert!(
                        diff < 1e-11,
                        "{name}: PB vs reference d={d} dt={dt} threads={threads}: |Δ|={diff}"
                    );
                    assert_eq!(
                        c_pb.data, c_csr.data,
                        "{name}: PB vs CSR not bit-for-bit (d={d} dt={dt} threads={threads})"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_pb_random_shapes_bands_and_tiles() {
    check_default(0x9b1, |rng| {
        let nr = 8 + rng.below_usize(120);
        let nc = 8 + rng.below_usize(120);
        let a = erdos_renyi(nr, nc, rng.range_f64(0.0, 8.0), rng);
        let d = 1 + rng.below_usize(18);
        let dt = 1 + rng.below_usize(d + 4); // sometimes > d (untiled)
        let threads = 1 + rng.below_usize(4);
        let col_band = 1 + rng.below_usize(40);
        let row_band = 1 + rng.below_usize(40);
        let b = DenseMatrix::random(nc, d, rng);
        let want = dense_spmm(&a, &b);
        let pb = PbSpmm::from_csr_with_bands(&a, col_band, row_band, threads);
        let mut c = DenseMatrix::zeros(nr, d);
        pb.execute_with(&b, &mut c, &pb.plan(Some(dt))).map_err(|e| e.to_string())?;
        let diff = c.max_abs_diff(&want);
        if diff > 1e-11 {
            return Err(format!(
                "PB ({nr}x{nc}, d={d}, dt={dt}, bands={col_band}/{row_band}): |Δ|={diff}"
            ));
        }
        // bitwise agreement with CSR holds for every band geometry
        let csr = CsrSpmm::new(a.clone(), threads);
        let mut c_csr = DenseMatrix::zeros(nr, d);
        csr.execute_with(&b, &mut c_csr, &csr.plan(Some(dt))).map_err(|e| e.to_string())?;
        if c.data != c_csr.data {
            return Err(format!(
                "PB vs CSR bitwise mismatch ({nr}x{nc}, d={d}, dt={dt}, \
                 bands={col_band}/{row_band})"
            ));
        }
        Ok(())
    });
}

/// The partition-boundary regression at the integration level: a
/// schedule whose partitions are single rows (every bucket straddles
/// partition boundaries) must neither drop nor double-count bucket
/// contributions, for every generator.
#[test]
fn prop_pb_one_row_partitions_never_double_count() {
    // small instances of every generator, so Schedule::uniform(n,
    // ⌈n/8⌉) degenerates to one row per partition and every 3-row
    // bucket straddles partition boundaries
    let mut rng = Prng::new(0x9b2);
    let suite: Vec<(&'static str, Csr)> = vec![
        ("banded", banded(24, 3, 0.5, &mut rng)),
        ("blocked", mesh2d(5, MeshKind::Triangular, 0.9, &mut rng)),
        ("er", erdos_renyi(30, 30, 4.0, &mut rng)),
        ("rmat", rmat(5, 4.0, 0.57, 0.19, 0.19, &mut rng)),
        (
            "scalefree",
            chung_lu(ChungLuParams { n: 40, alpha: 2.2, avg_deg: 5.0, k_min: 1.5 }, &mut rng),
        ),
    ];
    for (name, a) in suite {
        let d = 5;
        let b = DenseMatrix::random(a.ncols, d, &mut rng);
        let want = dense_spmm(&a, &b);
        let pb = PbSpmm::from_csr_with_bands(&a, 4, 3, 2);
        let s = Schedule::uniform(a.nrows, a.nrows.div_ceil(8)).with_tile(Some(2));
        assert_eq!(s.n_parts(), a.nrows, "{name}: schedule must be one row per partition");
        let mut c = DenseMatrix::from_vec(a.nrows, d, vec![99.0; a.nrows * d]);
        pb.execute_with(&b, &mut c, &s).unwrap();
        let diff = c.max_abs_diff(&want);
        assert!(diff < 1e-11, "{name}: adversarial schedule |Δ|={diff}");
    }
}

#[test]
fn prop_pb_one_row_partitions_small_matrices() {
    check_default(0x9b3, |rng| {
        // n ≤ 8·threads so Schedule::uniform degenerates to one row
        // per partition — the adversarial case for bucket ownership
        let n = 4 + rng.below_usize(28);
        let threads = n.div_ceil(8).max(1) + rng.below_usize(3);
        let a = erdos_renyi(n, n, rng.range_f64(1.0, 6.0), rng);
        let d = 1 + rng.below_usize(6);
        let row_band = 1 + rng.below_usize(7);
        let b = DenseMatrix::random(n, d, rng);
        let want = dense_spmm(&a, &b);
        let pb = PbSpmm::from_csr_with_bands(&a, 5, row_band, 2);
        let s = Schedule::uniform(n, threads);
        let mut c = DenseMatrix::from_vec(n, d, vec![3.5; n * d]);
        pb.execute_with(&b, &mut c, &s).map_err(|e| e.to_string())?;
        let diff = c.max_abs_diff(&want);
        if diff > 1e-11 {
            return Err(format!(
                "n={n} threads={threads} rb={row_band} d={d}: |Δ|={diff}"
            ));
        }
        Ok(())
    });
}
