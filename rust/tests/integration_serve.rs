//! Integration tests for the serving front-end's failure semantics:
//!
//! * a panicking kernel planted inside a coalesced batch
//!   ([`spmm_roofline::coordinator::Engine::install_kernel`], the
//!   fault-injection seam) fails **only its own jobs** — the group
//!   falls back to per-job isolation, healthy jobs still answer `Ok`,
//!   and the engine keeps serving afterwards (no pool or lock
//!   poisoning);
//! * a full queue answers `Submit::Rejected` immediately — admission
//!   control never blocks the producer (these tests run with no
//!   consumer draining: a blocking submit would hang them);
//! * shutdown drains: jobs accepted before `close()` are all
//!   executed and their tickets fulfilled;
//! * the `BENCH_route.json` merge path is concurrency-safe — the
//!   regression test for the read-modify-write race in
//!   `PerfLog::merge_save`, which now serialises through the
//!   `report::state` file lock + atomic rename. Interleaved writers
//!   with distinct bench names must all survive into the final file.

use std::sync::atomic::{AtomicUsize, Ordering};

use spmm_roofline::coordinator::{
    Engine, EngineConfig, JobSpec, ServeConfig, ServeRequest, Server, SpGemmSpec, Submit,
};
use spmm_roofline::error::Error;
use spmm_roofline::gen::{erdos_renyi, Prng};
use spmm_roofline::model::MachineParams;
use spmm_roofline::report::{PerfLog, PerfRecord};
use spmm_roofline::spmm::{DenseMatrix, Impl, Spmm};

fn test_engine(impls: Vec<Impl>) -> Engine {
    Engine::new(EngineConfig {
        threads: 2,
        machine: Some(MachineParams { beta_gbs: 10.0, pi_gflops: 100.0 }),
        iters: 1,
        warmup: 0,
        impls,
        artifacts_dir: None,
        ..EngineConfig::default()
    })
    .unwrap()
}

/// A kernel that panics on execute — planted under a real impl id to
/// poison exactly the jobs routed (here: forced) to it.
struct PanicSpmm {
    nrows: usize,
    ncols: usize,
}

impl Spmm for PanicSpmm {
    fn id(&self) -> Impl {
        Impl::Csb
    }
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn nnz(&self) -> usize {
        0
    }
    fn execute(&self, _b: &DenseMatrix, _c: &mut DenseMatrix) -> spmm_roofline::error::Result<()> {
        panic!("injected kernel fault");
    }
}

#[test]
fn panicking_kernel_inside_a_coalesced_batch_fails_only_its_jobs() {
    let mut rng = Prng::new(0xfa11);
    let m = erdos_renyi(80, 80, 4.0, &mut rng);
    let (nrows, ncols) = (m.nrows, m.ncols);
    let mut e = test_engine(vec![Impl::Csr, Impl::Csb]);
    e.register_for("acme", "m", m).unwrap();
    e.install_kernel("acme/m", Impl::Csb, Box::new(PanicSpmm { nrows, ncols })).unwrap();

    let mut server = Server::new(e, ServeConfig { queue_capacity: 16, ..ServeConfig::default() });
    let handle = server.handle();
    // six same-matrix jobs → one coalesced group; two of them are
    // forced onto the planted kernel
    let mut tickets = Vec::new();
    for tag in 0..6u64 {
        let spec = if tag % 3 == 0 {
            JobSpec::new("m", 4).with_impl(Impl::Csb) // will panic
        } else {
            JobSpec::new("m", 4).with_impl(Impl::Csr) // healthy
        };
        let req = ServeRequest::spmm("acme", spec, tag).with_tag(tag);
        tickets.push(handle.submit(req).unwrap().ticket().expect("queue has room"));
    }
    handle.close();
    server.run();

    for (tag, t) in tickets.iter().enumerate() {
        let r = t.try_take().expect("shutdown fulfilled every ticket");
        if tag % 3 == 0 {
            match r {
                Err(Error::Panic(msg)) => assert!(msg.contains("injected kernel fault"), "{msg}"),
                other => panic!("job {tag} should fail with the contained panic, got {other:?}"),
            }
        } else {
            let reply = r.unwrap_or_else(|e| panic!("healthy job {tag} must survive: {e}"));
            // the group fell back to per-job isolation
            assert!(!reply.coalesced, "a poisoned group must not report coalesced execution");
            assert_eq!(reply.output.dense().unwrap().len(), 80 * 4);
        }
    }
    assert_eq!(server.stats().jobs_done, 4);
    assert_eq!(server.stats().jobs_failed, 2);
    assert_eq!(server.stats().coalesced_jobs, 0, "fallback jobs are not coalesced");

    // no poisoning: the same engine keeps serving after the panics
    let rec = server.engine_mut().submit(&JobSpec::new("acme/m", 4).with_impl(Impl::Csr)).unwrap();
    assert_eq!(rec.chosen, Impl::Csr);
}

#[test]
fn full_queue_rejects_immediately_and_recovers_after_drain() {
    let mut rng = Prng::new(0x5b1e);
    let m = erdos_renyi(60, 60, 3.0, &mut rng);
    let mut e = test_engine(vec![Impl::Csr]);
    e.register_for("", "m", m).unwrap();
    let mut server = Server::new(e, ServeConfig { queue_capacity: 2, ..ServeConfig::default() });
    let handle = server.handle();

    // no consumer is running here — if admission blocked on a full
    // ring, this test would hang instead of seeing `Rejected`
    let req = |tag| {
        ServeRequest::spmm("", JobSpec::new("m", 4).with_impl(Impl::Csr), tag).with_tag(tag)
    };
    let t1 = handle.submit(req(1)).unwrap().ticket().unwrap();
    let t2 = handle.submit(req(2)).unwrap().ticket().unwrap();
    match handle.submit(req(3)).unwrap() {
        Submit::Rejected { queue_depth } => assert_eq!(queue_depth, 2),
        Submit::Accepted(_) => panic!("third job must hit backpressure"),
    }
    assert_eq!(handle.depth(), 2);

    handle.close();
    server.run();
    assert!(t1.try_take().unwrap().is_ok());
    assert!(t2.try_take().unwrap().is_ok());
    assert_eq!(server.stats().rejected, 1);
    assert_eq!(server.stats().jobs_done, 2);

    // post-shutdown submissions fail loudly instead of queueing
    assert!(handle.submit(req(4)).is_err(), "closed queue must refuse work");
}

#[test]
fn shutdown_drains_every_in_flight_job_under_concurrency() {
    let mut rng = Prng::new(0xd0a1);
    let m = erdos_renyi(70, 70, 3.0, &mut rng);
    let mut e = test_engine(vec![Impl::Csr]);
    e.register_for("acme", "m", m.clone()).unwrap();
    e.register_for("beta", "m", m).unwrap();
    let mut server = Server::new(e, ServeConfig { queue_capacity: 64, ..ServeConfig::default() });
    let handle = server.handle();
    let fulfilled = AtomicUsize::new(0);
    let clients = 3usize;
    let per_client = 5u64;
    let remaining = AtomicUsize::new(clients);
    std::thread::scope(|s| {
        for c in 0..clients {
            let h = handle.clone();
            let fulfilled = &fulfilled;
            let remaining = &remaining;
            s.spawn(move || {
                let tenant = if c % 2 == 0 { "acme" } else { "beta" };
                let mut tickets = Vec::new();
                for i in 0..per_client {
                    let tag = ((c as u64) << 8) | i;
                    let req = if i == 0 {
                        ServeRequest::spgemm(tenant, SpGemmSpec::new("m", "m")).with_tag(tag)
                    } else {
                        ServeRequest::spmm(tenant, JobSpec::new("m", 4).with_impl(Impl::Csr), tag)
                            .with_tag(tag)
                    };
                    tickets.push(h.submit(req).unwrap().ticket().expect("queue has room"));
                }
                // close races the server's drain loop: whatever was
                // accepted must still complete
                if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                    h.close();
                }
                for t in tickets {
                    t.wait().unwrap();
                    fulfilled.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
        server.run();
    });
    let total = clients * per_client as usize;
    assert_eq!(fulfilled.load(Ordering::Relaxed), total);
    assert_eq!(server.stats().jobs_done, total);
    assert_eq!(server.stats().jobs_failed, 0);
    assert_eq!(server.execution_log().len(), total);
}

/// Regression: `PerfLog::merge_save` used to read-modify-write the
/// merged JSON without any interlock — two concurrent writers could
/// both read the same base file and one's records would vanish. The
/// merge path now holds the snapshot file lock across the
/// read-merge-write and lands via atomic rename; interleaved writers
/// with distinct bench names must all survive, and foreign records
/// must be preserved verbatim.
#[test]
fn merge_save_interleaved_writers_preserve_every_bench() {
    let dir = std::env::temp_dir().join("spmm_roofline_integration_serve");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("BENCH_merge_{}.json", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    let _ = std::fs::remove_file(&path);

    // a pre-existing foreign record (another bench's artifact)
    let mut seed = PerfLog::new();
    seed.push(PerfRecord::basic("bench_foreign", "m0", "Uniform Random", "CSR", 4, 4, 1.5));
    seed.merge_save(&path).unwrap();

    let writers = 4usize;
    let rounds = 5usize;
    std::thread::scope(|s| {
        for w in 0..writers {
            let path = &path;
            s.spawn(move || {
                for r in 0..rounds {
                    let mut log = PerfLog::new();
                    log.push(PerfRecord::basic(
                        format!("bench_writer_{w}"),
                        format!("m{r}"),
                        "Uniform Random",
                        "CSR",
                        4,
                        4,
                        1.0 + r as f64,
                    ));
                    log.merge_save(path).unwrap();
                }
            });
        }
    });

    let back = PerfLog::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let bench_of = |b: &str| back.records.iter().filter(|r| r.bench == b).count();
    assert_eq!(bench_of("bench_foreign"), 1, "foreign records must survive the merges");
    for w in 0..writers {
        // merge_save replaces same-bench records, so each writer's
        // *last* round is what must survive — exactly one record
        assert_eq!(
            bench_of(&format!("bench_writer_{w}")),
            1,
            "writer {w}'s records were clobbered by an interleaved writer"
        );
    }
    assert_eq!(back.records.len(), 1 + writers);
    let _ = std::fs::remove_file(&path);
}
