//! Property tests for the concurrent serving front-end: whatever the
//! thread interleaving, the queue admission order, or the batch
//! coalescing did, every reply must be **bitwise identical** to a
//! sequential replay of the same job on a fresh engine.
//!
//! The replay protocol (and why it is sound):
//!
//! * every SpMM request carries its own operand seed, and the pooled
//!   dense operand is a pure function of `(rows, d, seed)` — recycled
//!   buffers are cleared and refilled entirely from the passed RNG;
//! * different kernels are *not* assumed bitwise-identical to each
//!   other, so the replay forces the impl the server actually chose
//!   (`JobRecord::chosen` / `SpGemmRecord::chosen`) — the property
//!   pins the serving layer, not cross-kernel accumulation order;
//! * autotune stays off here, so no reordering mutates layouts
//!   mid-run (the persistence property below turns it on and replays
//!   against the *pinned* decisions instead).
//!
//! Alongside: coalesced vs uncoalesced equality on an identical
//! request list, and the persisted-autotune-state property — snapshot
//! bytes round-trip exactly, a restarted server pins the same
//! decisions with zero new exploration, and a corrupted snapshot
//! cold-starts instead of panicking.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use spmm_roofline::coordinator::{
    AutotunePolicy, Engine, EngineConfig, JobSpec, ServeConfig, ServeReply, ServeRequest,
    ServeWork, Server, SpGemmSpec, Submit, WorkloadOutcome,
};
use spmm_roofline::gen::{banded, erdos_renyi, mesh2d, MeshKind, Prng};
use spmm_roofline::model::MachineParams;
use spmm_roofline::report::AutotuneState;
use spmm_roofline::sparse::Csr;
use spmm_roofline::spgemm::SpGemmImpl;
use spmm_roofline::spmm::Impl;
use spmm_roofline::testutil::{assert_close_slice, assert_csr_eq, check};

fn serve_engine(autotune: AutotunePolicy) -> Engine {
    Engine::new(EngineConfig {
        threads: 2,
        machine: Some(MachineParams { beta_gbs: 10.0, pi_gflops: 100.0 }),
        iters: 1,
        warmup: 0,
        impls: vec![Impl::Csr, Impl::Opt, Impl::Csb],
        artifacts_dir: None,
        autotune,
    })
    .unwrap()
}

/// A small structurally-mixed matrix set for one case. The same
/// matrices are registered into the serving engine and the replay
/// engine, under two tenants: `m0`/`m1` exist in both (shared local
/// names — the tenant scoping must keep them apart), `m2` only under
/// `acme` (disjoint).
fn case_matrices(rng: &mut Prng) -> Vec<(&'static str, Csr)> {
    // m0 is square: the scripts submit its self-product
    let n0 = 90 + rng.below_usize(40);
    vec![
        ("m0", erdos_renyi(n0, n0, 4.0, rng)),
        ("m1", banded(80 + rng.below_usize(40), 4, 0.5, rng)),
        ("m2", mesh2d(9, MeshKind::Triangular, 0.9, rng)),
    ]
}

fn register_all(e: &mut Engine, mats: &[(&'static str, Csr)]) {
    for (name, m) in mats {
        e.register_for("acme", name, m.clone()).unwrap();
        if *name != "m2" {
            e.register_for("beta", name, m.clone()).unwrap();
        }
    }
}

/// The per-client request script: a seeded SpMM/SpGemm mix over
/// shared and (for acme) disjoint matrices, tags globally unique.
fn client_script(c: usize, case_seed: u64, rng: &mut Prng) -> Vec<ServeRequest> {
    let tenant = if c % 2 == 0 { "acme" } else { "beta" };
    let mut out = Vec::new();
    let mut tag = (c as u64) << 32;
    let n_jobs = 3 + rng.below_usize(4); // 3..=6 per client
    for i in 0..n_jobs {
        let pick = rng.below_usize(4);
        if pick == 3 {
            // sparse×sparse leg on a shared matrix
            out.push(ServeRequest::spgemm(tenant, SpGemmSpec::new("m0", "m0")).with_tag(tag));
        } else {
            let name = if pick == 2 && tenant == "acme" {
                "m2"
            } else if pick == 1 {
                "m1"
            } else {
                "m0"
            };
            let d = [3usize, 5, 8][rng.below_usize(3)];
            let seed = case_seed ^ ((c as u64) << 16) ^ (i as u64);
            out.push(ServeRequest::spmm(tenant, JobSpec::new(name, d), seed).with_tag(tag));
        }
        tag += 1;
    }
    out
}

/// Drive a server with `clients` concurrent threads submitting the
/// given scripts; returns every reply keyed by tag. The queue is
/// sized to the full offered load, so nothing is rejected and every
/// request must come back exactly once.
fn serve_concurrently(
    mut server: Server,
    scripts: &[Vec<ServeRequest>],
) -> (HashMap<u64, ServeReply>, Server) {
    let total: usize = scripts.iter().map(|s| s.len()).sum();
    let handle = server.handle();
    let remaining = AtomicUsize::new(scripts.len());
    let replies: Mutex<HashMap<u64, ServeReply>> = Mutex::new(HashMap::new());
    std::thread::scope(|s| {
        for script in scripts {
            let h = handle.clone();
            let remaining = &remaining;
            let replies = &replies;
            s.spawn(move || {
                let mut tickets = Vec::new();
                for req in script {
                    match h.submit(req.clone()).unwrap() {
                        Submit::Accepted(t) => tickets.push(t),
                        Submit::Rejected { queue_depth } => {
                            panic!("queue sized for the full load rejected at {queue_depth}")
                        }
                    }
                }
                if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                    h.close();
                }
                let mut got = HashMap::new();
                for t in tickets {
                    let r = t.wait().unwrap();
                    assert!(got.insert(r.tag, r).is_none(), "duplicate tag in replies");
                }
                replies.lock().unwrap().extend(got);
            });
        }
        server.run();
    });
    let replies = replies.into_inner().unwrap();
    assert_eq!(replies.len(), total, "every accepted job must be answered");
    (replies, server)
}

/// Replay one served request sequentially on the given engine,
/// forcing the impl the server chose, and demand bitwise equality.
fn replay_one(e: &mut Engine, req: &ServeRequest, reply: &ServeReply) {
    match (&req.work, &reply.outcome) {
        (ServeWork::SpMM { spec, seed }, WorkloadOutcome::SpMM(rec)) => {
            assert_eq!(rec.d, spec.d);
            let forced = Server::scoped_spmm(&req.tenant, spec).with_impl(rec.chosen);
            let (rec2, out2) = e.submit_collect(&forced, *seed).unwrap();
            assert_eq!(rec2.chosen, rec.chosen);
            let got = reply.output.dense().expect("SpMM reply carries a dense product");
            assert_close_slice(got, &out2, 0.0);
        }
        (ServeWork::SpGemm { spec }, WorkloadOutcome::SpGemm(rec)) => {
            let mut forced = Server::scoped_spgemm(&req.tenant, spec);
            forced.force_impl = Some(rec.chosen);
            let (rec2, c2) = e.submit_spgemm_collect(&forced).unwrap();
            assert_eq!(rec2.chosen, rec.chosen);
            let got = reply.output.sparse().expect("SpGEMM reply carries a CSR product");
            assert_csr_eq(got, &c2, 0.0);
        }
        _ => panic!("reply workload kind does not match its request"),
    }
}

/// Tentpole property: 2–8 client threads × seeded SpMM/SpGEMM mixes
/// over shared and disjoint matrices — every concurrent (possibly
/// coalesced) result equals the sequential replay, bit for bit.
#[test]
fn concurrent_serving_is_bitwise_equal_to_sequential_replay() {
    check(0x5e21e, 4, |rng| {
        let case_seed = rng.next_u64();
        let mats = case_matrices(rng);
        let clients = 2 + rng.below_usize(7); // 2..=8
        let scripts: Vec<Vec<ServeRequest>> =
            (0..clients).map(|c| client_script(c, case_seed, rng)).collect();
        let total: usize = scripts.iter().map(|s| s.len()).sum();
        let by_tag: HashMap<u64, ServeRequest> =
            scripts.iter().flatten().map(|r| (r.tag, r.clone())).collect();
        assert_eq!(by_tag.len(), total, "tags must be unique");

        let mut e1 = serve_engine(AutotunePolicy::default());
        register_all(&mut e1, &mats);
        let server = Server::new(
            e1,
            ServeConfig { queue_capacity: total.max(1), max_drain: 5, ..ServeConfig::default() },
        );
        let (replies, server) = serve_concurrently(server, &scripts);
        assert_eq!(server.stats().jobs_done, total);
        assert_eq!(server.stats().jobs_failed, 0);
        assert_eq!(server.execution_log().len(), total);

        let mut e2 = serve_engine(AutotunePolicy::default());
        register_all(&mut e2, &mats);
        for (tag, reply) in &replies {
            replay_one(&mut e2, &by_tag[tag], reply);
        }
        Ok(())
    });
}

/// Coalescing is a pure scheduling optimisation: the same
/// single-client request list served with coalescing on and off
/// yields bitwise-identical outputs per tag (and the coalescing
/// server really did merge something).
#[test]
fn coalesced_and_uncoalesced_servers_agree_bitwise() {
    check(0xc0a1, 3, |rng| {
        let case_seed = rng.next_u64();
        let mats = case_matrices(rng);
        // One script with repeated same-matrix jobs → mergeable pairs.
        // Impls are forced: the on/off runs route independently, and
        // unforced priors drift with timing — the property under test
        // is the *coalescing*, not cross-kernel bit-equality.
        let mut script: Vec<ServeRequest> = client_script(0, case_seed, rng)
            .into_iter()
            .map(|mut r| {
                match &mut r.work {
                    ServeWork::SpMM { spec, .. } => spec.force_impl = Some(Impl::Csr),
                    ServeWork::SpGemm { spec } => spec.force_impl = Some(SpGemmImpl::Hash),
                }
                r
            })
            .collect();
        let dup: Vec<ServeRequest> = script
            .iter()
            .filter(|r| matches!(r.work, ServeWork::SpMM { .. }))
            .map(|r| r.clone().with_tag(r.tag | (1 << 60)))
            .collect();
        assert!(!dup.is_empty(), "script must contain SpMM work");
        script.extend(dup);

        let mut run = |coalesce: bool| {
            let mut e = serve_engine(AutotunePolicy::default());
            register_all(&mut e, &mats);
            // single-threaded protocol: enqueue everything, close,
            // then drain — fully deterministic
            let mut server = Server::new(
                e,
                ServeConfig { queue_capacity: script.len(), coalesce, ..ServeConfig::default() },
            );
            let handle = server.handle();
            let tickets: Vec<_> = script
                .iter()
                .map(|r| handle.submit(r.clone()).unwrap().ticket().expect("sized queue"))
                .collect();
            handle.close();
            server.run();
            let replies: Vec<ServeReply> = tickets.iter().map(|t| t.wait().unwrap()).collect();
            (replies, server.stats().clone())
        };
        let (on, on_stats) = run(true);
        let (off, off_stats) = run(false);
        assert!(on_stats.coalesced_jobs > 0, "duplicated SpMM jobs must coalesce");
        assert_eq!(off_stats.coalesced_jobs, 0, "coalescing was off");
        assert_eq!(on_stats.jobs_done, off_stats.jobs_done);
        for (a, b) in on.iter().zip(&off) {
            assert_eq!(a.tag, b.tag, "ticket order is submission order");
            match (&a.output, &b.output) {
                (o1, o2) if o1.dense().is_some() => assert_close_slice(
                    o1.dense().unwrap(),
                    o2.dense().expect("kind must match"),
                    0.0,
                ),
                (o1, o2) => assert_csr_eq(o1.sparse().unwrap(), o2.sparse().unwrap(), 0.0),
            }
        }
        Ok(())
    });
}

fn temp_state_path(tag: &str, case: u64) -> String {
    let dir = std::env::temp_dir().join("spmm_roofline_prop_serve");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("state_{}_{}_{}.json", tag, std::process::id(), case))
        .to_str()
        .unwrap()
        .to_string()
}

/// Persistence property: the snapshot a serving run saves at shutdown
/// round-trips byte-identically through parse→emit, and a second
/// server constructed over the same registrations loads it, pins the
/// same decisions, and serves the same mix with **zero** new
/// exploration measurements. A corrupted or truncated snapshot must
/// cold-start (with a warning) instead of panicking.
#[test]
fn persisted_state_round_trips_and_skips_exploration() {
    check(0x9e51, 3, |rng| {
        let case_seed = rng.next_u64();
        let path = temp_state_path("rt", case_seed);
        let _ = std::fs::remove_file(&path);
        let mats = case_matrices(rng);
        let scripts: Vec<Vec<ServeRequest>> =
            (0..2).map(|c| client_script(c, case_seed, rng)).collect();
        let quick = AutotunePolicy {
            explore_iters: 1,
            explore_min_secs: 0.0,
            ..AutotunePolicy::enabled()
        };

        // run 1: tune while serving, persist at shutdown
        let mut e1 = serve_engine(quick.clone());
        register_all(&mut e1, &mats);
        let server = Server::new(
            e1,
            ServeConfig {
                queue_capacity: 64,
                state_path: Some(path.clone()),
                ..ServeConfig::default()
            },
        );
        assert!(!server.restored(), "nothing to restore on the first run");
        let (_, server) = serve_concurrently(server, &scripts);
        let explored = server.engine().autotuner().measurements();
        assert!(explored > 0, "first run must explore");
        drop(server);

        // byte-exact round trip: file → parse → emit → same bytes
        let bytes1 = std::fs::read_to_string(&path).unwrap();
        let state = AutotuneState::load(&path).unwrap();
        assert!(!state.is_empty());
        assert_eq!(state.to_json(), bytes1, "save→load→save must be byte-identical");

        // run 2: restored server serves the same mix without exploring
        let mut e2 = serve_engine(quick.clone());
        register_all(&mut e2, &mats);
        let server2 = Server::new(
            e2,
            ServeConfig {
                queue_capacity: 64,
                state_path: Some(path.clone()),
                ..ServeConfig::default()
            },
        );
        assert!(server2.restored(), "snapshot must load");
        let (_, server2) = serve_concurrently(server2, &scripts);
        assert_eq!(
            server2.engine().autotuner().measurements(),
            0,
            "restored decisions pin every job — zero new exploration"
        );
        // and the decisions themselves are the run-1 decisions
        let again = server2.engine().export_state();
        assert_eq!(again.routes.len(), state.routes.len());
        for (a, b) in again.routes.iter().zip(&state.routes) {
            assert_eq!(
                (a.matrix.clone(), a.d, a.im, a.reorder),
                (b.matrix.clone(), b.d, b.im, b.reorder)
            );
        }
        drop(server2);

        // corruption: truncate the snapshot mid-record → cold start
        let truncated = &bytes1[..bytes1.len() / 2];
        std::fs::write(&path, truncated).unwrap();
        let mut e3 = serve_engine(quick);
        register_all(&mut e3, &mats);
        let server3 = Server::new(
            e3,
            ServeConfig {
                queue_capacity: 64,
                state_path: Some(path.clone()),
                ..ServeConfig::default()
            },
        );
        assert!(!server3.restored(), "corrupt snapshot must cold-start, not panic");
        let _ = std::fs::remove_file(&path);
        Ok(())
    });
}
