//! Integration: the roofline-guided engine end to end (classify →
//! predict → route → measure → learn), without XLA (see
//! integration_runtime for the artifact path).

use spmm_roofline::coordinator::{Engine, EngineConfig, JobSpec};
use spmm_roofline::gen::{representative_suite, SparsityClass};
use spmm_roofline::model::MachineParams;
use spmm_roofline::spmm::Impl;

fn engine() -> Engine {
    Engine::new(EngineConfig {
        threads: 1,
        machine: Some(MachineParams { beta_gbs: 8.0, pi_gflops: 60.0 }),
        iters: 1,
        warmup: 0,
        impls: vec![Impl::Csr, Impl::Opt, Impl::Csb],
        artifacts_dir: None,
        ..EngineConfig::default()
    })
    .unwrap()
}

#[test]
fn engine_runs_the_representative_suite() {
    let mut e = engine();
    for proxy in representative_suite() {
        e.register(proxy.name, proxy.generate(0.03)).unwrap();
    }
    let mut jobs = Vec::new();
    for name in e.registry().names() {
        for d in [1usize, 16] {
            jobs.push(JobSpec::new(name.to_string(), d));
        }
    }
    let records = e.run_batch(&jobs).unwrap();
    assert_eq!(records.len(), 8);
    for r in &records {
        assert!(r.measured_gflops > 0.0, "{}: no throughput", r.matrix);
        assert!(r.predicted_gflops > 0.0);
        assert!(r.ai > 0.0);
    }
    // classes must match the suite's provenance
    for proxy in representative_suite() {
        let cls = &e.registry().get(proxy.name).unwrap().classification;
        assert_eq!(cls.class, proxy.class, "{}", proxy.name);
    }
}

#[test]
fn routing_sends_blocked_to_csb_and_learns() {
    let mut e = engine();
    let road = representative_suite()
        .into_iter()
        .find(|p| p.class == SparsityClass::Blocked)
        .unwrap();
    e.register("road", road.generate(0.03)).unwrap();
    let rec = e.submit(&JobSpec::new("road", 16)).unwrap();
    assert_eq!(rec.chosen, Impl::Csb, "blocked matrix should route to CSB initially");

    // measure every impl so the report can score routing
    for im in [Impl::Csr, Impl::Opt, Impl::Csb] {
        e.submit(&JobSpec::new("road", 16).with_impl(im)).unwrap();
    }
    let rep = e.prediction_report();
    assert_eq!(rep.n_jobs, 4);
    assert!(rep.geomean_ratio > 0.0);
    assert!(rep.routing_hit_rate.is_some());
}

#[test]
fn engine_survives_many_widths_and_reuses_kernels() {
    let mut e = engine();
    let er = representative_suite()
        .into_iter()
        .find(|p| p.class == SparsityClass::Random)
        .unwrap();
    e.register("er", er.generate(0.03)).unwrap();
    for d in [1usize, 2, 3, 5, 8, 13, 21, 34] {
        let rec = e.submit(&JobSpec::new("er", d)).unwrap();
        assert_eq!(rec.d, d);
    }
    assert_eq!(e.history().len(), 8);
}
