//! Property tests over the cache simulator and failure injection over
//! the kernel layer: invariants that hold for arbitrary access
//! streams and hostile inputs.

use spmm_roofline::cachesim::{Cache, CacheConfig, Hierarchy, HierarchyConfig};
use spmm_roofline::gen::{erdos_renyi, Prng};
use spmm_roofline::sparse::Csr;
use spmm_roofline::spmm::{build_native, reference_spmm, DenseMatrix, Impl};
use spmm_roofline::testutil::check_default;

#[test]
fn prop_cache_misses_bounded_by_accesses_and_compulsory() {
    check_default(0x400, |rng| {
        let mut c = Cache::new(CacheConfig {
            size_bytes: 1 << (9 + rng.below(6) as u32),
            line_bytes: 64,
            ways: 1 << rng.below(4) as u32,
        });
        let span = 1u64 << (10 + rng.below(8) as u32);
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..2000 {
            let addr = rng.below(span);
            distinct.insert(addr >> 6);
            c.access(addr);
        }
        let s = c.stats;
        if s.misses > s.accesses {
            return Err("misses exceed accesses".into());
        }
        // at least one miss per distinct line (compulsory)
        if (s.misses as usize) < distinct.len() {
            return Err(format!(
                "misses {} below compulsory floor {}",
                s.misses,
                distinct.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_bigger_cache_never_misses_more_lru() {
    // LRU inclusion property: doubling capacity (same ways×2) cannot
    // increase misses on the same trace
    check_default(0x401, |rng| {
        let trace: Vec<u64> = (0..3000).map(|_| rng.below(1 << 14)).collect();
        let mut small = Cache::new(CacheConfig { size_bytes: 4 << 10, line_bytes: 64, ways: 4 });
        let mut big = Cache::new(CacheConfig { size_bytes: 8 << 10, line_bytes: 64, ways: 8 });
        for &a in &trace {
            small.access(a);
            big.access(a);
        }
        if big.stats.misses > small.stats.misses {
            return Err(format!(
                "bigger cache missed more: {} vs {}",
                big.stats.misses, small.stats.misses
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_hierarchy_dram_bounded_by_l1_misses() {
    check_default(0x402, |rng| {
        let mut h = Hierarchy::new(HierarchyConfig::tiny());
        for _ in 0..2000 {
            h.load(rng.below(1 << 20), 8);
        }
        let r = h.report();
        // every DRAM line fill corresponds to an L3 miss; L3 misses ≤ L2 ≤ L1
        if r.l3.misses > r.l2.misses || r.l2.misses > r.l1.misses {
            return Err("miss counts not monotone down the hierarchy".into());
        }
        if r.dram_bytes != r.l3.misses * 64 {
            return Err("DRAM bytes != L3 misses × line".into());
        }
        Ok(())
    });
}

// ---- failure injection over the kernel layer ----------------------

#[test]
fn kernels_propagate_nan_and_inf_like_the_reference() {
    let mut rng = Prng::new(0x403);
    let a = erdos_renyi(120, 120, 5.0, &mut rng);
    let mut b = DenseMatrix::random(120, 4, &mut rng);
    b.set(3, 1, f64::NAN);
    b.set(60, 0, f64::INFINITY);
    let want = reference_spmm(&a, &b);
    for im in Impl::NATIVE {
        let k = build_native(im, &a, 2).unwrap();
        let mut c = DenseMatrix::zeros(120, 4);
        k.execute(&b, &mut c).unwrap();
        for i in 0..c.data.len() {
            let (x, y) = (c.data[i], want.data[i]);
            // NaN/Inf must propagate; finite values may differ by FMA
            // reassociation (OPT's 2-way unroll). ELL is special: its
            // zero-valued padding slots still *gather* B rows, and
            // 0 × Inf = NaN, so ELL may poison rows whose padding
            // happens to point at a non-finite B row — a documented
            // semantic property of padded formats (the XLA artifact
            // shares it). Non-padded formats must match exactly.
            let same = (x.is_nan() && y.is_nan())
                || x == y
                || (x.is_finite() && y.is_finite() && (x - y).abs() < 1e-10)
                || (im == Impl::Ell && x.is_nan());
            assert!(same, "{im}: slot {i} {x} vs {y}");
        }
    }
}

#[test]
fn kernels_handle_degenerate_shapes() {
    // 1×1, single row, single column, fully dense row
    let cases = vec![
        Csr::from_dense(1, 1, &[2.0]),
        Csr::from_dense(1, 5, &[1.0, 0.0, 2.0, 0.0, 3.0]),
        Csr::from_dense(5, 1, &[1.0, 0.0, 2.0, 0.0, 3.0]),
    ];
    let mut rng = Prng::new(0x404);
    for a in cases {
        let b = DenseMatrix::random(a.ncols, 3, &mut rng);
        let want = reference_spmm(&a, &b);
        for im in Impl::NATIVE {
            let k = build_native(im, &a, 4).unwrap();
            let mut c = DenseMatrix::zeros(a.nrows, 3);
            k.execute(&b, &mut c).unwrap();
            assert!(
                c.max_abs_diff(&want) < 1e-12,
                "{im} on {}x{}",
                a.nrows,
                a.ncols
            );
        }
    }
}

#[test]
fn validate_rejects_corrupted_structures() {
    let mut rng = Prng::new(0x405);
    let a = erdos_renyi(50, 50, 4.0, &mut rng);
    // corrupt a column index out of range
    let mut bad = a.clone();
    if bad.nnz() > 0 {
        bad.col_idx[0] = 1000;
        assert!(bad.validate().is_err());
    }
    // corrupt row_ptr monotonicity
    let mut bad = a.clone();
    if bad.nrows > 2 {
        bad.row_ptr[1] = bad.row_ptr[2] + 1;
        assert!(bad.validate().is_err());
    }
}

#[test]
fn prop_more_threads_never_change_any_structure_result() {
    check_default(0x406, |rng| {
        let n = 16 + rng.below_usize(100);
        let a = erdos_renyi(n, n, rng.range_f64(0.5, 8.0), rng);
        let d = 1 + rng.below_usize(9);
        let b = DenseMatrix::random(n, d, rng);
        let want = reference_spmm(&a, &b);
        let threads = 1 + rng.below_usize(8);
        for im in Impl::NATIVE {
            let k = build_native(im, &a, threads).map_err(|e| e.to_string())?;
            let mut c = DenseMatrix::zeros(n, d);
            k.execute(&b, &mut c).map_err(|e| e.to_string())?;
            if c.max_abs_diff(&want) > 1e-11 {
                return Err(format!("{im} with {threads} threads diverged"));
            }
        }
        Ok(())
    });
}
