//! Property tests for the learned structure router.
//!
//! Four properties, each over seeded random cases (`PROP_SEED` folds a
//! fleet-wide offset into every seed — see `testutil`):
//!
//! 1. **In-distribution reproduction** — a forest trained on feature
//!    points from five structurally distinct generator families, each
//!    family labeled with its own `(impl, reorder, dt)` triple, never
//!    routes a training point to another family's label, and
//!    confidently reproduces the label on at least half of them (the
//!    rest may fall back through the confidence/support gates — a
//!    fallback is correct behaviour, a cross-family answer is a bug).
//! 2. **Off-distribution fallback** — any query outside the training
//!    ranges returns `None` (the analytic fallback), and arbitrary
//!    finite or non-finite query vectors never panic.
//! 3. **Snapshot round trip** — a trained forest embedded in an
//!    `AutotuneState` survives save → load → save byte-identically and
//!    routes identically after the round trip.
//! 4. **Malformed snapshots reject** — truncation, a dropped tree
//!    node, and an out-of-range confidence gate each reject the whole
//!    snapshot at parse (`Err`, never a half-loaded forest).

use spmm_roofline::coordinator::{
    features_of, Example, LearnedRouter, RouteLabel, TrainConfig,
};
use spmm_roofline::gen::{
    banded, chung_lu, erdos_renyi, mesh2d, rmat, ChungLuParams, MeshKind, Prng,
};
use spmm_roofline::model::{FeatureVec, N_FEATURES};
use spmm_roofline::pattern::classify;
use spmm_roofline::report::AutotuneState;
use spmm_roofline::sparse::{Csr, Reordering};
use spmm_roofline::spmm::Impl;
use spmm_roofline::testutil::check;

/// One labeled family: a generator plus the plan triple that "wins"
/// on it. The labels are synthetic ground truth — the property tests
/// the forest's ability to reproduce a consistent mapping, not kernel
/// performance.
struct Family {
    name: &'static str,
    label: RouteLabel,
    gen: fn(&mut Prng) -> Csr,
}

fn families() -> Vec<Family> {
    vec![
        Family {
            name: "erdos_renyi",
            label: RouteLabel { im: Impl::Csr, reorder: Reordering::None, dt: 16 },
            gen: |rng| {
                let n = 150 + rng.below_usize(100);
                erdos_renyi(n, n, 4.0 + rng.below_usize(4) as f64, rng)
            },
        },
        Family {
            name: "banded",
            label: RouteLabel { im: Impl::Csb, reorder: Reordering::Rcm, dt: 8 },
            gen: |rng| banded(150 + rng.below_usize(100), 3 + rng.below_usize(4), 0.8, rng),
        },
        Family {
            name: "mesh2d",
            label: RouteLabel { im: Impl::Opt, reorder: Reordering::Rcm, dt: 16 },
            gen: |rng| mesh2d(12 + rng.below_usize(6), MeshKind::Triangular, 0.9, rng),
        },
        Family {
            name: "chung_lu",
            label: RouteLabel { im: Impl::Pb, reorder: Reordering::DegreeSort, dt: 8 },
            gen: |rng| {
                chung_lu(
                    ChungLuParams {
                        n: 300 + rng.below_usize(150),
                        alpha: 2.2,
                        avg_deg: 8.0,
                        k_min: 2.0,
                    },
                    rng,
                )
            },
        },
        Family {
            name: "rmat",
            label: RouteLabel { im: Impl::Ell, reorder: Reordering::DegreeSort, dt: 4 },
            gen: |rng| rmat(8, 6.0, 0.57, 0.19, 0.19, rng),
        },
    ]
}

/// Training set: `per_family` instances of each family at a couple of
/// dense widths, all labeled with the family's triple.
fn training_set(per_family: usize, rng: &mut Prng) -> Vec<Example> {
    let mut out = Vec::new();
    for fam in families() {
        for _ in 0..per_family {
            let m = (fam.gen)(rng);
            let cls = classify(&m);
            let d = [8usize, 32][rng.below_usize(2)];
            out.push(Example { features: features_of(&cls, d), label: fam.label });
        }
    }
    out
}

#[test]
fn forest_reproduces_family_labels_in_distribution() {
    check(0x1ea7_0001, 6, |rng| {
        let examples = training_set(4, rng);
        let router = LearnedRouter::train(&examples, &TrainConfig::default())
            .map_err(|e| format!("train failed: {e}"))?;
        router.validate().map_err(|e| format!("fresh forest invalid: {e}"))?;
        let mut confident = 0usize;
        for (i, ex) in examples.iter().enumerate() {
            match router.route(&ex.features) {
                // a gated fallback is fine; a cross-family answer is not
                None => {}
                Some(got) => {
                    let want = ex.label;
                    if (got.im, got.reorder, got.dt) != (want.im, want.reorder, want.dt) {
                        return Err(format!(
                            "training point {i} routed to {}/{}/{} instead of {}/{}/{}",
                            got.im, got.reorder, got.dt, want.im, want.reorder, want.dt
                        ));
                    }
                    if !(got.confidence > 0.0 && got.confidence <= 1.0) {
                        return Err(format!("confidence {} out of (0,1]", got.confidence));
                    }
                    confident += 1;
                }
            }
        }
        if confident * 2 < examples.len() {
            return Err(format!(
                "only {confident}/{} training points reproduced confidently",
                examples.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn off_distribution_queries_fall_back_and_never_panic() {
    check(0x1ea7_0002, 6, |rng| {
        let examples = training_set(3, rng);
        let router = LearnedRouter::train(&examples, &TrainConfig::default())
            .map_err(|e| format!("train failed: {e}"))?;
        // push each feature in turn far past its training range: the
        // forest must refuse to extrapolate
        for f in 0..N_FEATURES {
            let (lo, hi) = router.ranges[f];
            let span = (hi - lo).max(1.0);
            let mut high = [0.0; N_FEATURES];
            let mut low = [0.0; N_FEATURES];
            for (g, &(glo, ghi)) in router.ranges.iter().enumerate() {
                // otherwise mid-range, so feature f is the sole excursion
                high[g] = 0.5 * (glo + ghi);
                low[g] = 0.5 * (glo + ghi);
            }
            high[f] = hi + 2.0 * span;
            low[f] = lo - 2.0 * span;
            if router.route(&FeatureVec::from_raw(high)).is_some() {
                return Err(format!("feature {f} above range did not fall back"));
            }
            if router.route(&FeatureVec::from_raw(low)).is_some() {
                return Err(format!("feature {f} below range did not fall back"));
            }
        }
        // arbitrary garbage — huge magnitudes, negatives, non-finite
        // (sanitized to 0 by construction) — must never panic
        for _ in 0..50 {
            let mut v = [0.0; N_FEATURES];
            for x in v.iter_mut() {
                *x = match rng.below(5) {
                    0 => f64::NAN,
                    1 => f64::INFINITY,
                    2 => -1e300,
                    3 => rng.below(1_000_000) as f64,
                    _ => rng.below(1000) as f64 / 997.0,
                };
            }
            let _ = router.route(&FeatureVec::from_raw(v));
        }
        Ok(())
    });
}

#[test]
fn trained_forest_snapshot_round_trips_byte_identically() {
    check(0x1ea7_0003, 6, |rng| {
        let examples = training_set(3, rng);
        let router = LearnedRouter::train(&examples, &TrainConfig::default())
            .map_err(|e| format!("train failed: {e}"))?;
        let state = AutotuneState { learned: Some(router.clone()), ..Default::default() };
        let j1 = state.to_json();
        let back = AutotuneState::parse(&j1).map_err(|e| format!("parse failed: {e}"))?;
        let j2 = back.to_json();
        if j1 != j2 {
            return Err("save → load → save is not byte-identical".into());
        }
        let restored = back.learned.ok_or("forest lost in round trip")?;
        if restored != router {
            return Err("restored forest differs structurally".into());
        }
        // and it routes identically — on training points and on
        // perturbed near-distribution points alike
        for ex in examples.iter() {
            let mut probe = ex.features.0;
            probe[rng.below_usize(N_FEATURES)] *= 1.0 + (rng.below(100) as f64 - 50.0) / 1000.0;
            for q in [ex.features, FeatureVec::from_raw(probe)] {
                if router.route(&q) != restored.route(&q) {
                    return Err("restored forest routes differently".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn malformed_forest_snapshots_reject_at_parse() {
    check(0x1ea7_0004, 6, |rng| {
        let examples = training_set(3, rng);
        let router = LearnedRouter::train(&examples, &TrainConfig::default())
            .map_err(|e| format!("train failed: {e}"))?;
        let state = AutotuneState { learned: Some(router), ..Default::default() };
        let json = state.to_json();

        // raw truncation anywhere inside the records fails the
        // wrapper-integrity check
        let cut = json.len() / 2 + rng.below_usize(json.len() / 4);
        if AutotuneState::parse(&json[..cut]).is_ok() {
            return Err("truncated snapshot parsed".into());
        }

        // dropping the final tree node (and re-closing the wrapper)
        // leaves a dangling child reference or an empty tree — the
        // structural validate must reject it whole
        let last = json
            .rfind(",\n  {\"kind\": \"learned_node\"")
            .ok_or("no learned_node records emitted")?;
        let dropped = format!("{}\n]}}\n", &json[..last]);
        if AutotuneState::parse(&dropped).is_ok() {
            return Err("snapshot with a missing tree node parsed".into());
        }

        // an impossible confidence gate (> 1) fails the range check
        let skewed = json.replace("\"min_conf\": 0.65", "\"min_conf\": 1.65");
        if skewed == json {
            return Err("expected the default 0.65 confidence gate in the snapshot".into());
        }
        if AutotuneState::parse(&skewed).is_ok() {
            return Err("snapshot with confidence gate > 1 parsed".into());
        }
        Ok(())
    });
}
