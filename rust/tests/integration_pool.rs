//! Integration tests for the persistent worker pool and the batched
//! engine path: pool reuse across calls, nested and concurrent
//! submission safety, kernel-output equivalence through the shared
//! pool, and batch-path determinism + buffer reuse.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use spmm_roofline::coordinator::{Engine, EngineConfig, JobSpec};
use spmm_roofline::gen::{banded, chung_lu, erdos_renyi, ChungLuParams, Prng};
use spmm_roofline::model::MachineParams;
use spmm_roofline::spmm::{build_native, pool, reference_spmm, DenseMatrix, Impl};

/// Every native kernel must match the serial reference when its row
/// loops run across the shared persistent pool.
#[test]
fn kernels_match_reference_through_shared_pool() {
    let mut rng = Prng::new(0xA11);
    let cases = vec![
        ("er", erdos_renyi(400, 400, 6.0, &mut rng)),
        ("banded", banded(400, 5, 1.0, &mut rng)),
        (
            "skewed",
            chung_lu(ChungLuParams { n: 400, alpha: 2.1, avg_deg: 8.0, k_min: 2.0 }, &mut rng),
        ),
    ];
    for (name, a) in cases {
        for d in [1usize, 4, 16] {
            let b = DenseMatrix::random(400, d, &mut rng);
            let want = reference_spmm(&a, &b);
            for im in Impl::NATIVE {
                let k = build_native(im, &a, 4).unwrap();
                let mut c = DenseMatrix::from_vec(400, d, vec![7.0; 400 * d]);
                k.execute(&b, &mut c).unwrap();
                assert!(
                    c.max_abs_diff(&want) < 1e-10,
                    "{im} diverged on {name} at d={d}"
                );
            }
        }
    }
}

/// Sequential calls must keep running on the same small persistent
/// thread set — no per-call spawning.
#[test]
fn global_pool_reuses_threads_across_calls() {
    let ids = Mutex::new(HashSet::new());
    for _ in 0..100 {
        pool::parallel_ranges(256, 8, |_r| {
            ids.lock().unwrap().insert(std::thread::current().id());
        });
    }
    let distinct = ids.lock().unwrap().len();
    // at most: every pool worker + this (submitting) test thread
    assert!(
        distinct <= pool::global().workers() + 1,
        "{distinct} distinct threads for 100 calls — pool is spawning"
    );
}

/// A parallel loop issued from inside a pool job must run inline (no
/// deadlock) and still cover every index.
#[test]
fn nested_submission_is_safe() {
    let sum = AtomicU64::new(0);
    pool::parallel_ranges(6, 3, |outer| {
        for _ in outer {
            pool::parallel_chunks_dynamic(50, 4, 8, |inner| {
                sum.fetch_add(inner.len() as u64, Ordering::Relaxed);
            });
        }
    });
    assert_eq!(sum.load(Ordering::Relaxed), 6 * 50);
}

/// Independent threads submitting to the shared pool at the same time
/// must each see a complete, exactly-once traversal.
#[test]
fn concurrent_submissions_are_serialised_safely() {
    std::thread::scope(|s| {
        for t in 0..4 {
            s.spawn(move || {
                for round in 0..20 {
                    let n = 300 + 31 * t + round;
                    let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
                    pool::parallel_chunks_dynamic(n, 3, 13, |r| {
                        for i in r {
                            hits[i].fetch_add(1, Ordering::Relaxed);
                        }
                    });
                    assert!(
                        hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                        "thread {t} round {round}: lost or duplicated work"
                    );
                }
            });
        }
    });
}

fn test_engine() -> Engine {
    Engine::new(EngineConfig {
        threads: 2,
        machine: Some(MachineParams { beta_gbs: 10.0, pi_gflops: 100.0 }),
        iters: 1,
        warmup: 0,
        impls: vec![Impl::Csr, Impl::Opt, Impl::Csb],
        artifacts_dir: None,
        ..EngineConfig::default()
    })
    .unwrap()
}

/// The batched path must be deterministic in everything the planner
/// controls: classification, model AI, and (forced) routing — across
/// two engines built from the same seeds.
#[test]
fn batch_path_is_deterministic() {
    let jobs: Vec<JobSpec> = [4usize, 16]
        .iter()
        .flat_map(|&d| {
            [Impl::Csr, Impl::Opt, Impl::Csb]
                .into_iter()
                .map(move |im| JobSpec::new("m", d).with_impl(im))
        })
        .collect();
    let run = || {
        let mut e = test_engine();
        let a = erdos_renyi(500, 500, 6.0, &mut Prng::new(0xDE7));
        e.register("m", a).unwrap();
        e.submit_batch(&jobs).unwrap()
    };
    let r1 = run();
    let r2 = run();
    assert_eq!(r1.n_jobs(), 6);
    assert_eq!(r1.n_jobs(), r2.n_jobs());
    for (a, b) in r1.records.iter().zip(&r2.records) {
        assert_eq!(a.matrix, b.matrix);
        assert_eq!(a.d, b.d);
        assert_eq!(a.chosen, b.chosen);
        assert_eq!(a.class, b.class);
        assert_eq!(a.ai, b.ai, "model AI must not depend on timing or buffer reuse");
    }
}

/// Across batches the engine's buffer pool must go fully warm: the
/// second identical batch allocates nothing.
#[test]
fn second_batch_runs_on_recycled_buffers() {
    let mut e = test_engine();
    let a = erdos_renyi(300, 300, 5.0, &mut Prng::new(0xB1F));
    e.register("m", a).unwrap();
    let jobs = vec![JobSpec::new("m", 8), JobSpec::new("m", 8), JobSpec::new("m", 8)];
    let cold = e.submit_batch(&jobs).unwrap();
    assert!(cold.buffer_hits > 0, "within-batch reuse expected");
    let warm = e.submit_batch(&jobs).unwrap();
    assert_eq!(warm.buffer_misses, 0, "second batch must be fully recycled");
    assert!(warm.buffer_hit_rate() > 0.99);
    // measurements stay sane through recycled buffers
    assert!(warm.aggregate_gflops() > 0.0);
}
