//! Integration: every SpMM implementation × every generator × every
//! paper d agrees with the serial reference.

use spmm_roofline::gen::{
    banded, chung_lu, erdos_renyi, ideal_diagonal, mesh2d, rmat, ChungLuParams, MeshKind, Prng,
};
use spmm_roofline::sparse::Csr;
use spmm_roofline::spmm::{build_native, reference_spmm, DenseMatrix, Impl};

fn generators() -> Vec<(&'static str, Csr)> {
    let mut rng = Prng::new(0xF00D);
    vec![
        ("er", erdos_renyi(600, 600, 7.0, &mut rng)),
        ("banded", banded(600, 6, 0.4, &mut rng)),
        ("ideal_diag", ideal_diagonal(600)),
        ("mesh_road", mesh2d(25, MeshKind::Road, 0.62, &mut rng)),
        ("mesh_tri", mesh2d(25, MeshKind::Triangular, 0.9, &mut rng)),
        (
            "chung_lu",
            chung_lu(ChungLuParams { n: 600, alpha: 2.2, avg_deg: 10.0, k_min: 2.0 }, &mut rng),
        ),
        ("rmat", rmat(9, 8.0, 0.57, 0.19, 0.19, &mut rng)),
        ("empty", Csr::from_dense(64, 64, &[0.0; 4096])),
    ]
}

#[test]
fn all_impls_match_reference_on_all_structures() {
    let mut rng = Prng::new(0xBEEF);
    for (name, a) in generators() {
        a.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        for d in [1usize, 4, 16, 64] {
            let b = DenseMatrix::random(a.ncols, d, &mut rng);
            let want = reference_spmm(&a, &b);
            for im in Impl::NATIVE {
                let k = build_native(im, &a, 2).unwrap();
                let mut c = DenseMatrix::zeros(a.nrows, d);
                k.execute(&b, &mut c).unwrap();
                let diff = c.max_abs_diff(&want);
                assert!(diff < 1e-11, "{name}/{im}/d={d}: max|Δ|={diff}");
            }
        }
    }
}

#[test]
fn thread_counts_do_not_change_results() {
    let mut rng = Prng::new(0xCAFE);
    let a = chung_lu(ChungLuParams { n: 900, alpha: 2.1, avg_deg: 14.0, k_min: 2.0 }, &mut rng);
    let b = DenseMatrix::random(900, 8, &mut rng);
    let want = reference_spmm(&a, &b);
    for im in Impl::NATIVE {
        for threads in [1usize, 2, 3, 7] {
            let k = build_native(im, &a, threads).unwrap();
            let mut c = DenseMatrix::zeros(900, 8);
            k.execute(&b, &mut c).unwrap();
            assert!(
                c.max_abs_diff(&want) < 1e-11,
                "{im} with {threads} threads diverged"
            );
        }
    }
}

#[test]
fn repeated_execution_is_idempotent() {
    let mut rng = Prng::new(0xD00D);
    let a = erdos_renyi(400, 400, 6.0, &mut rng);
    let b = DenseMatrix::random(400, 16, &mut rng);
    for im in Impl::NATIVE {
        let k = build_native(im, &a, 2).unwrap();
        let mut c1 = DenseMatrix::zeros(400, 16);
        let mut c2 = DenseMatrix::random(400, 16, &mut rng); // stale garbage
        k.execute(&b, &mut c1).unwrap();
        k.execute(&b, &mut c2).unwrap();
        assert_eq!(c1.data, c2.data, "{im} not idempotent over stale C");
    }
}

#[test]
fn mismatched_shapes_error_not_panic() {
    let a = erdos_renyi(100, 100, 3.0, &mut Prng::new(5));
    for im in Impl::NATIVE {
        let k = build_native(im, &a, 1).unwrap();
        let b_bad = DenseMatrix::zeros(99, 4);
        let mut c = DenseMatrix::zeros(100, 4);
        assert!(k.execute(&b_bad, &mut c).is_err(), "{im} accepted bad B");
        let b = DenseMatrix::zeros(100, 4);
        let mut c_bad = DenseMatrix::zeros(100, 3);
        assert!(k.execute(&b, &mut c_bad).is_err(), "{im} accepted bad C");
    }
}
