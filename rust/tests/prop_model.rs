//! Property tests over the analytic models: bounds, monotonicity and
//! dimensional sanity of Eqs. 2/3/4/6 across random parameter draws.

use spmm_roofline::gen::Prng;
use spmm_roofline::model::{
    ai_blocked, ai_diagonal, ai_random, ai_scalefree, expected_z, expected_z_exact,
    hub_mass_fraction, AiParams, MachineParams, Roofline,
};
use spmm_roofline::testutil::check_default;

fn arb_params(rng: &mut Prng) -> AiParams {
    let n = 1usize << (10 + rng.below(12) as u32); // 2^10..2^21
    let deg = 1.0 + rng.range_f64(0.0, 100.0);
    let d = 1 + rng.below_usize(128);
    AiParams::new(n, d, (n as f64 * deg) as usize)
}

#[test]
fn prop_random_model_is_the_floor() {
    // The universal invariant (§III): random = worst case. Cross-
    // structure orderings are NOT universal (Eq. 4 charges 8 B/nnz for
    // A vs Eq. 3's 12, so blocked can exceed diagonal at low density).
    check_default(0x300, |rng| {
        let p = arb_params(rng);
        let r = ai_random(p);
        let di = ai_diagonal(p);
        let t = 1usize << (4 + rng.below(10) as u32);
        let n_blocks = (p.nnz / (1 + rng.below_usize(64))).max(1);
        let bl = ai_blocked(p, t, n_blocks);
        let alpha = rng.range_f64(2.01, 2.99);
        let f = rng.range_f64(0.0001, 0.05);
        let sf = ai_scalefree(p, alpha, f);
        if !(r > 0.0 && di > 0.0 && bl > 0.0 && sf > 0.0) {
            return Err("non-positive AI".into());
        }
        if r > di * 1.001 {
            return Err(format!("random {r} > diagonal {di}"));
        }
        if bl < r * 0.999 {
            return Err(format!("blocked {bl} below random floor {r}"));
        }
        if sf < r * 0.999 {
            return Err(format!("scale-free {sf} below random floor {r}"));
        }
        // absolute ceiling: no model beats "A values+idx once, C once"
        let ceiling = p.flops() / (8.0 * p.nnz as f64 + 8.0 * (p.n * p.d) as f64);
        for (name, ai) in [("blocked", bl), ("scale-free", sf), ("diagonal", di)] {
            if ai > ceiling * 1.001 {
                return Err(format!("{name} AI {ai} above physical ceiling {ceiling}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ai_random_increases_with_d_saturating() {
    check_default(0x301, |rng| {
        let p = arb_params(rng);
        let mut last = 0.0;
        for d in [1usize, 2, 4, 8, 16, 32, 64, 128] {
            let ai = ai_random(AiParams { d, ..p });
            if ai < last {
                return Err(format!("AI(random) not monotone at d={d}"));
            }
            last = ai;
        }
        // saturation: AI(random) < 2/8 = 0.25 always (B re-read per nnz)
        if last >= 0.25 {
            return Err(format!("AI(random) {last} above the 1/4 asymptote"));
        }
        Ok(())
    });
}

#[test]
fn prop_hub_mass_bounds_and_monotonicity() {
    check_default(0x302, |rng| {
        let alpha = rng.range_f64(2.01, 3.5);
        let f = rng.range_f64(1e-5, 1.0);
        let m = hub_mass_fraction(alpha, f);
        if !(0.0..=1.0).contains(&m) {
            return Err(format!("hub mass {m} out of [0,1]"));
        }
        if m < f * 0.999 {
            return Err(format!("hubs hold less ({m}) than their node share ({f})"));
        }
        let m2 = hub_mass_fraction(alpha, (f * 2.0).min(1.0));
        if m2 < m * 0.999 {
            return Err("hub mass not monotone in f".into());
        }
        Ok(())
    });
}

#[test]
fn prop_z_bounds_and_poisson_error() {
    check_default(0x303, |rng| {
        let t = 2.0 + rng.range_f64(0.0, 8192.0);
        let d = rng.range_f64(0.0, 10_000.0);
        let z = expected_z(t, d);
        if z < 0.0 || z > t + 1e-9 {
            return Err(format!("z={z} outside [0, t={t}]"));
        }
        if z > d + 1e-9 && d < t {
            // can't occupy more columns than nonzeros
            return Err(format!("z={z} > D={d}"));
        }
        let exact = expected_z_exact(t, d);
        if (z - exact).abs() > 0.08 * exact.max(1.0) {
            return Err(format!("Poisson approx off: {z} vs {exact} (t={t}, D={d})"));
        }
        Ok(())
    });
}

#[test]
fn prop_roofline_min_semantics() {
    check_default(0x304, |rng| {
        let beta = rng.range_f64(1.0, 500.0);
        let pi = rng.range_f64(10.0, 5000.0);
        let m = MachineParams { beta_gbs: beta, pi_gflops: pi };
        let roofline = Roofline::new(m);
        let ai = rng.range_f64(0.001, 100.0);
        let p = roofline.attainable_gflops(ai);
        if p > pi + 1e-9 || p > beta * ai + 1e-9 {
            return Err("P exceeds a roof".into());
        }
        if (p - (beta * ai).min(pi)).abs() > 1e-9 {
            return Err("P ≠ min(β·AI, π)".into());
        }
        if roofline.memory_bound(ai) != (ai < m.ridge_ai()) {
            return Err("memory_bound inconsistent with ridge".into());
        }
        Ok(())
    });
}

#[test]
fn prop_bytes_positive_and_flops_consistent() {
    check_default(0x305, |rng| {
        let p = arb_params(rng);
        use spmm_roofline::model::{bytes_diagonal, bytes_random};
        for (ai, bytes) in [
            (ai_random(p), bytes_random(p)),
            (ai_diagonal(p), bytes_diagonal(p)),
        ] {
            if bytes <= 0.0 {
                return Err("non-positive bytes".into());
            }
            if ((p.flops() / bytes) - ai).abs() > 1e-12 * ai {
                return Err("AI ≠ FLOPs/bytes".into());
            }
        }
        Ok(())
    });
}
