//! Property tests over the SpMM kernels: algebraic identities that
//! must hold for every implementation on every random structure.
//!
//! The reference side is the shared differential oracle
//! ([`spmm_roofline::testutil::dense_spmm`]) — a dense triple loop
//! independent of every CSR traversal, so a bug shared by the kernels
//! cannot cancel out of the comparison.

use spmm_roofline::gen::{erdos_renyi, Prng};
use spmm_roofline::sparse::Csr;
use spmm_roofline::spmm::{build_native, DenseMatrix, Impl};
use spmm_roofline::testutil::{check_default, close_slice, dense_spmm};

fn arb_square(rng: &mut Prng) -> Csr {
    let n = 8 + rng.below_usize(120);
    let deg = rng.range_f64(0.0, 10.0);
    erdos_renyi(n, n, deg, rng)
}

#[test]
fn prop_all_impls_agree_with_reference() {
    check_default(0x200, |rng| {
        let a = arb_square(rng);
        let d = 1 + rng.below_usize(20);
        let threads = 1 + rng.below_usize(3);
        let b = DenseMatrix::random(a.ncols, d, rng);
        let want = dense_spmm(&a, &b);
        for im in Impl::NATIVE {
            let k = build_native(im, &a, threads).map_err(|e| e.to_string())?;
            let mut c = DenseMatrix::zeros(a.nrows, d);
            k.execute(&b, &mut c).map_err(|e| e.to_string())?;
            close_slice(
                &c.data,
                &want.data,
                1e-11,
                &format!("{im} (threads={threads}, d={d})"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_linearity_in_b() {
    // A·(αB₁ + B₂) == α(A·B₁) + A·B₂
    check_default(0x201, |rng| {
        let a = arb_square(rng);
        let d = 1 + rng.below_usize(8);
        let alpha = rng.range_f64(-2.0, 2.0);
        let b1 = DenseMatrix::random(a.ncols, d, rng);
        let b2 = DenseMatrix::random(a.ncols, d, rng);
        let mut combo = DenseMatrix::zeros(a.ncols, d);
        for i in 0..combo.data.len() {
            combo.data[i] = alpha * b1.data[i] + b2.data[i];
        }
        let k = build_native(Impl::Opt, &a, 1).map_err(|e| e.to_string())?;
        let mut c_combo = DenseMatrix::zeros(a.nrows, d);
        k.execute(&combo, &mut c_combo).map_err(|e| e.to_string())?;
        let c1 = dense_spmm(&a, &b1);
        let c2 = dense_spmm(&a, &b2);
        for i in 0..c_combo.data.len() {
            let want = alpha * c1.data[i] + c2.data[i];
            if (c_combo.data[i] - want).abs() > 1e-9 {
                return Err(format!("linearity broken at {i}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_identity_matrix_is_noop() {
    check_default(0x202, |rng| {
        let n = 8 + rng.below_usize(100);
        let a = spmm_roofline::gen::ideal_diagonal(n);
        let d = 1 + rng.below_usize(8);
        let b = DenseMatrix::random(n, d, rng);
        for im in Impl::NATIVE {
            let k = build_native(im, &a, 1).map_err(|e| e.to_string())?;
            let mut c = DenseMatrix::zeros(n, d);
            k.execute(&b, &mut c).map_err(|e| e.to_string())?;
            if c.max_abs_diff(&b) > 1e-12 {
                return Err(format!("{im}: I·B ≠ B"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_zero_matrix_gives_zero() {
    check_default(0x203, |rng| {
        let n = 4 + rng.below_usize(64);
        let a = Csr::from_dense(n, n, &vec![0.0; n * n]);
        let d = 1 + rng.below_usize(6);
        let b = DenseMatrix::random(n, d, rng);
        for im in Impl::NATIVE {
            let k = build_native(im, &a, 2).map_err(|e| e.to_string())?;
            let mut c = DenseMatrix::random(n, d, rng); // stale
            k.execute(&b, &mut c).map_err(|e| e.to_string())?;
            if c.data.iter().any(|&x| x != 0.0) {
                return Err(format!("{im}: 0·B ≠ 0"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_spmv_equals_spmm_column() {
    // d=1 SpMV must equal each column of a d>1 SpMM
    check_default(0x204, |rng| {
        let a = arb_square(rng);
        let d = 2 + rng.below_usize(6);
        let b = DenseMatrix::random(a.ncols, d, rng);
        let full = dense_spmm(&a, &b);
        let k = build_native(Impl::Csr, &a, 1).map_err(|e| e.to_string())?;
        for col in 0..d {
            let mut bcol = DenseMatrix::zeros(a.ncols, 1);
            for r in 0..a.ncols {
                bcol.data[r] = b.get(r, col);
            }
            let mut c = DenseMatrix::zeros(a.nrows, 1);
            k.execute(&bcol, &mut c).map_err(|e| e.to_string())?;
            for r in 0..a.nrows {
                if (c.data[r] - full.get(r, col)).abs() > 1e-11 {
                    return Err(format!("spmv col {col} row {r} mismatch"));
                }
            }
        }
        Ok(())
    });
}
