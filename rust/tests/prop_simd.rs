//! Property tests over the SIMD micro-kernel layer (`spmm::simd`):
//! every kernel × dispatch variant (forced-scalar vs runtime-dispatched)
//! must be **bitwise identical** — the primitives perform one rounded
//! multiply and one rounded add per element in the same order at every
//! width — across the five structural generators, `dt ∈ {1, 3, d−1, d}`,
//! threads ∈ {1, 4}, and adversarial row-length mixes (empty rows, one
//! giant row, all-singleton rows) stressing the nnz row bins.

use std::sync::Mutex;

use spmm_roofline::gen::{
    banded, chung_lu, erdos_renyi, mesh2d, rmat, ChungLuParams, MeshKind, Prng,
};
use spmm_roofline::sparse::{Coo, Csr};
use spmm_roofline::spmm::simd::{force_scalar, level, SimdLevel};
use spmm_roofline::spmm::{build_native, DenseMatrix, Impl};
use spmm_roofline::testutil::{check_default, dense_spmm};

/// Dispatch-state mutations are process-global: every test that forces
/// scalar serialises through this lock (mirroring the unit tests inside
/// `spmm::simd`).
static FORCE_LOCK: Mutex<()> = Mutex::new(());

/// One matrix per structural regime (the prop_pb suite), sized for
/// test speed.
fn generator_suite(rng: &mut Prng) -> Vec<(&'static str, Csr)> {
    vec![
        ("banded", banded(180, 6, 0.4, rng)),
        ("blocked", mesh2d(14, MeshKind::Triangular, 0.9, rng)),
        ("er", erdos_renyi(200, 200, 6.0, rng)),
        ("rmat", rmat(8, 6.0, 0.57, 0.19, 0.19, rng)),
        (
            "scalefree",
            chung_lu(ChungLuParams { n: 250, alpha: 2.2, avg_deg: 8.0, k_min: 2.0 }, rng),
        ),
    ]
}

/// Run one kernel twice — forced scalar, then runtime-dispatched — on
/// stale output buffers, and demand bitwise equality plus closeness to
/// the dense oracle. Caller holds `FORCE_LOCK`.
fn assert_dispatch_bitwise(
    tag: &str,
    k: &dyn spmm_roofline::spmm::Spmm,
    b: &DenseMatrix,
    want: &DenseMatrix,
    s: &spmm_roofline::spmm::Schedule,
    nrows: usize,
    d: usize,
) {
    force_scalar(true);
    let mut c_scalar = DenseMatrix::from_vec(nrows, d, vec![11.5; nrows * d]);
    k.execute_with(b, &mut c_scalar, s).unwrap();
    force_scalar(false);
    let mut c_auto = DenseMatrix::from_vec(nrows, d, vec![-3.25; nrows * d]);
    k.execute_with(b, &mut c_auto, s).unwrap();
    assert_eq!(
        c_scalar.data, c_auto.data,
        "{tag}: forced-scalar and dispatched ({}) outputs differ bitwise",
        level()
    );
    let diff = c_auto.max_abs_diff(want);
    assert!(diff < 1e-11, "{tag}: |Δ| vs dense reference = {diff}");
}

/// The acceptance grid: every native kernel × every generator ×
/// dt ∈ {1, 3, d−1, d} × threads ∈ {1, 4}, forced-scalar vs
/// runtime-dispatched bitwise.
#[test]
fn every_kernel_bitwise_equal_across_dispatch_variants() {
    let _g = FORCE_LOCK.lock().unwrap();
    let mut rng = Prng::new(0x51d0);
    for (name, a) in generator_suite(&mut rng) {
        for d in [4usize, 16] {
            let b = DenseMatrix::random(a.ncols, d, &mut rng);
            let want = dense_spmm(&a, &b);
            for threads in [1usize, 4] {
                for im in Impl::NATIVE {
                    let k = build_native(im, &a, threads).unwrap();
                    for dt in [1usize, 3, d - 1, d] {
                        let s = k.plan(Some(dt));
                        let tag = format!("{name}/{im} d={d} dt={dt} threads={threads}");
                        assert_dispatch_bitwise(&tag, k.as_ref(), &b, &want, &s, a.nrows, d);
                    }
                }
            }
        }
    }
    force_scalar(false);
}

/// Adversarial row-length mixes: one giant row, alternating
/// empty/singleton rows, and a block of medium rows — every nnz bin
/// (short/medium/long) populated, every kernel, both dispatch legs.
#[test]
fn adversarial_row_mixes_bitwise_across_dispatch() {
    let _g = FORCE_LOCK.lock().unwrap();
    check_default(0x51d1, |rng| {
        let n = 24 + rng.below_usize(60);
        let mut coo = Coo::new(n, n);
        let giant = rng.below_usize(n);
        for c in 0..n {
            coo.push(giant, c, rng.range_f64(-1.0, 1.0));
        }
        for r in 0..n {
            if r == giant {
                continue;
            }
            match r % 3 {
                0 => {} // empty row
                1 => coo.push(r, rng.below_usize(n), rng.range_f64(-1.0, 1.0)),
                _ => {
                    for _ in 0..(5 + rng.below_usize(8)) {
                        coo.push(r, rng.below_usize(n), rng.range_f64(-1.0, 1.0));
                    }
                }
            }
        }
        let a = Csr::from_coo(coo);
        let d = 1 + rng.below_usize(12);
        let dt = 1 + rng.below_usize(d);
        let threads = 1 + rng.below_usize(4);
        let b = DenseMatrix::random(n, d, rng);
        let want = dense_spmm(&a, &b);
        for im in Impl::NATIVE {
            let k = build_native(im, &a, threads).map_err(|e| e.to_string())?;
            let s = k.plan(Some(dt));
            force_scalar(true);
            let mut c1 = DenseMatrix::zeros(n, d);
            k.execute_with(&b, &mut c1, &s).map_err(|e| e.to_string())?;
            force_scalar(false);
            let mut c2 = DenseMatrix::from_vec(n, d, vec![7.0; n * d]);
            k.execute_with(&b, &mut c2, &s).map_err(|e| e.to_string())?;
            if c1.data != c2.data {
                return Err(format!(
                    "{im}: dispatch variants differ bitwise (n={n} d={d} dt={dt} \
                     threads={threads})"
                ));
            }
            let diff = c2.max_abs_diff(&want);
            if diff > 1e-11 {
                return Err(format!("{im}: |Δ|={diff} (n={n} d={d} dt={dt})"));
            }
        }
        force_scalar(false);
        Ok(())
    });
}

/// All-singleton rows: the short bin's 1-nnz path end to end, with
/// negative values guarding the `-0.0` hazard (a kernel that shortcut
/// a single-nonzero row straight into `C` would flip `-0.0` to `+0.0`
/// when the product lands on a zeroed tile).
#[test]
fn all_singleton_rows_bitwise_and_exact() {
    let _g = FORCE_LOCK.lock().unwrap();
    let mut rng = Prng::new(0x51d2);
    let n = 96;
    let mut coo = Coo::new(n, n);
    for r in 0..n {
        coo.push(r, (r * 7) % n, rng.range_f64(-2.0, 2.0));
    }
    let a = Csr::from_coo(coo);
    assert_eq!(a.nnz(), n);
    for d in [1usize, 2, 3, 5, 8] {
        let b = DenseMatrix::random(n, d, &mut rng);
        let want = dense_spmm(&a, &b);
        for im in Impl::NATIVE {
            let k = build_native(im, &a, 2).unwrap();
            let s = k.plan(Some(d));
            let tag = format!("singleton/{im} d={d}");
            assert_dispatch_bitwise(&tag, k.as_ref(), &b, &want, &s, n, d);
        }
    }
    force_scalar(false);
}

/// The probe resolves to a coherent level with a sane lane count, and
/// forcing scalar round-trips.
#[test]
fn dispatch_level_is_coherent() {
    let _g = FORCE_LOCK.lock().unwrap();
    force_scalar(true);
    assert_eq!(level(), SimdLevel::Scalar);
    force_scalar(false);
    let l = level();
    assert!(matches!(l, SimdLevel::Scalar | SimdLevel::Sse2 | SimdLevel::Avx));
    assert!([1usize, 2, 4].contains(&l.lanes()));
}
