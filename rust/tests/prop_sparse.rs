//! Property tests (proptest_lite) over the sparse-format substrate:
//! conversion round-trips, structural invariants, and IO.

use spmm_roofline::gen::{erdos_renyi, Prng};
use spmm_roofline::sparse::{mm_io, Coo, Csb, Csc, Csr, Ell};
use spmm_roofline::testutil::check_default;

/// A random small matrix with random shape/density per case.
fn arb_matrix(rng: &mut Prng) -> Csr {
    let nrows = 1 + rng.below_usize(80);
    let ncols = 1 + rng.below_usize(80);
    let deg = rng.range_f64(0.0, 8.0);
    erdos_renyi(nrows, ncols, deg, rng)
}

#[test]
fn prop_coo_csr_roundtrip() {
    check_default(0x100, |rng| {
        let a = arb_matrix(rng);
        let back = Csr::from_coo(a.to_coo());
        if back != a {
            return Err("COO→CSR→COO not identity".into());
        }
        back.validate().map_err(|e| e.to_string())
    });
}

#[test]
fn prop_csc_preserves_dense() {
    check_default(0x101, |rng| {
        let a = arb_matrix(rng);
        let csc = Csc::from_csr(&a);
        csc.validate().map_err(|e| e.to_string())?;
        if csc.to_dense() != a.to_dense() {
            return Err("CSC dense mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_csb_preserves_dense_any_block() {
    check_default(0x102, |rng| {
        let a = arb_matrix(rng);
        let block = 1usize << (rng.below(7) as u32); // 1..64
        let csb = Csb::from_csr_with_block(&a, block);
        csb.validate().map_err(|e| e.to_string())?;
        if csb.to_dense() != a.to_dense() {
            return Err(format!("CSB(block={block}) dense mismatch"));
        }
        if csb.nnz() != a.nnz() {
            return Err("CSB nnz mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_ell_preserves_dense_and_counts_padding() {
    check_default(0x103, |rng| {
        let a = arb_matrix(rng);
        let extra = rng.below_usize(4);
        let width = a.max_row_len().max(1) + extra;
        let ell = Ell::from_csr_with_width(&a, width);
        ell.validate().map_err(|e| e.to_string())?;
        if ell.to_dense() != a.to_dense() {
            return Err("ELL dense mismatch".into());
        }
        if ell.padded_len() != a.nrows * width {
            return Err("ELL padded_len wrong".into());
        }
        Ok(())
    });
}

#[test]
fn prop_transpose_involution() {
    check_default(0x104, |rng| {
        let a = arb_matrix(rng);
        let tt = a.transpose().transpose();
        if tt != a {
            return Err("transpose∘transpose ≠ id".into());
        }
        Ok(())
    });
}

#[test]
fn prop_matrixmarket_roundtrip() {
    let dir = std::env::temp_dir().join("spmm_prop_mmio");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("roundtrip.mtx");
    check_default(0x105, |rng| {
        let a = arb_matrix(rng);
        mm_io::write_csr(&path, &a).map_err(|e| e.to_string())?;
        let back = Csr::from_coo(mm_io::read_coo(&path).map_err(|e| e.to_string())?);
        // values survive to 17 significant digits
        if back.nrows != a.nrows || back.ncols != a.ncols || back.nnz() != a.nnz() {
            return Err("shape/nnz changed over MatrixMarket".into());
        }
        let (da, db) = (a.to_dense(), back.to_dense());
        for (x, y) in da.iter().zip(&db) {
            if (x - y).abs() > 1e-15 * x.abs().max(1.0) {
                return Err(format!("value drift {x} vs {y}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_symmetrize_is_symmetric_and_idempotent_on_pattern() {
    check_default(0x106, |rng| {
        let a = arb_matrix(rng);
        let n = a.nrows.max(a.ncols);
        // embed in square shape first
        let mut coo = Coo::new(n, n);
        for r in 0..a.nrows {
            for (c, v) in a.row_cols(r).iter().zip(a.row_vals(r)) {
                coo.push(r, *c as usize, *v);
            }
        }
        let sym = Csr::from_coo(coo.symmetrize());
        let d = sym.to_dense();
        for r in 0..n {
            for c in 0..n {
                if (d[r * n + c] != 0.0) != (d[c * n + r] != 0.0) {
                    return Err(format!("pattern asymmetric at ({r},{c})"));
                }
            }
        }
        Ok(())
    });
}
