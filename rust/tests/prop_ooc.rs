//! Differential wall around out-of-core execution: band-by-band SpMM
//! ([`OocSpmm`]) must be **bitwise identical** to whole-matrix
//! [`CsrSpmm`] across the structural generator suite, every dense
//! width, tile width, thread count, and band budget — including the
//! adversarial geometries (single-row bands, empty rows, hub rows, a
//! file-backed symmetric source whose mirror ordering must replay the
//! oracle's duplicate-summation order).

use std::path::PathBuf;

use spmm_roofline::gen::{
    banded, chung_lu, erdos_renyi, mesh2d, rmat, ChungLuParams, MeshKind, Prng,
};
use spmm_roofline::sparse::mm_io::{band_bytes, write_csr, write_csr_symmetric};
use spmm_roofline::sparse::{Coo, Csr, OocCsr, OocSpmm};
use spmm_roofline::spmm::{CsrSpmm, DenseMatrix, Spmm};
use spmm_roofline::testutil::{check_default, dense_spmm};

/// One matrix per structural regime (the shared generator suite).
fn generator_suite(rng: &mut Prng) -> Vec<(&'static str, Csr)> {
    vec![
        ("banded", banded(180, 6, 0.4, rng)),
        ("blocked", mesh2d(14, MeshKind::Triangular, 0.9, rng)),
        ("er", erdos_renyi(200, 200, 6.0, rng)),
        ("rmat", rmat(8, 6.0, 0.57, 0.19, 0.19, rng)),
        (
            "scalefree",
            chung_lu(ChungLuParams { n: 250, alpha: 2.2, avg_deg: 8.0, k_min: 2.0 }, rng),
        ),
    ]
}

/// Budgets forcing one band, a few bands, and one band per row.
fn budget_ladder(a: &Csr) -> [usize; 3] {
    [usize::MAX, band_bytes(a.nrows, a.nnz()) / 2 + 1, 0]
}

fn tmp_path(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("spmm_roofline_prop_ooc");
    std::fs::create_dir_all(&dir).expect("temp dir");
    dir.join(format!("{tag}.mtx"))
}

/// Whole-matrix CSR result for (a, b, dt, threads) with a stale-C
/// prefill.
fn csr_result(a: &Csr, b: &DenseMatrix, dt: usize, threads: usize) -> Vec<f64> {
    let kern = CsrSpmm::new(a.clone(), threads);
    let s = kern.plan(Some(dt));
    let mut c = DenseMatrix::from_vec(a.nrows, b.ncols, vec![13.0; a.nrows * b.ncols]);
    kern.execute_with(b, &mut c, &s).expect("CSR execute");
    c.data
}

/// Band-by-band result for the same cell, asserting the expected band
/// structure actually materialized.
fn ooc_result(
    ooc: OocCsr,
    b: &DenseMatrix,
    dt: usize,
    threads: usize,
    min_bands: usize,
) -> Vec<f64> {
    let nrows = ooc.nrows();
    assert!(ooc.n_bands() >= min_bands, "plan has {} bands, wanted ≥{min_bands}", ooc.n_bands());
    let kern = OocSpmm::new(ooc, threads);
    let s = kern.plan(Some(dt));
    let mut c = DenseMatrix::from_vec(nrows, b.ncols, vec![-7.0; nrows * b.ncols]);
    kern.execute_with(b, &mut c, &s).expect("OOC execute");
    c.data
}

/// The acceptance grid: every generator × d ∈ {3, 8, 16} × threads ∈
/// {1, 4} × dt ∈ {1, 3, d−1, d} × budgets forcing {1, ≥2, nrows}
/// bands — OOC vs whole-matrix CSR bit for bit, and vs the dense
/// reference within tolerance.
#[test]
fn ooc_matches_csr_bitwise_across_generators() {
    let mut rng = Prng::new(0x00cc);
    for (name, a) in generator_suite(&mut rng) {
        for d in [3usize, 8, 16] {
            let b = DenseMatrix::random(a.ncols, d, &mut rng);
            let want = dense_spmm(&a, &b);
            for threads in [1usize, 4] {
                for dt in [1usize, 3, d - 1, d] {
                    let whole = csr_result(&a, &b, dt, threads);
                    for (bi, budget) in budget_ladder(&a).into_iter().enumerate() {
                        let min_bands = [1usize, 2, a.nrows][bi];
                        let got = ooc_result(
                            OocCsr::from_csr(a.clone(), budget),
                            &b,
                            dt,
                            threads,
                            min_bands,
                        );
                        assert_eq!(
                            got, whole,
                            "{name}: OOC ≠ CSR (d={d} dt={dt} threads={threads} budget={budget})"
                        );
                        let diff = got
                            .iter()
                            .zip(&want.data)
                            .map(|(x, y)| (x - y).abs())
                            .fold(0.0f64, f64::max);
                        assert!(diff < 1e-11, "{name}: OOC vs reference |Δ|={diff}");
                    }
                }
            }
        }
    }
}

/// File-backed bands (general banner): re-streaming the file per band
/// must land on the identical bits as the resident slices.
#[test]
fn file_backed_general_matches_in_memory_bitwise() {
    let mut rng = Prng::new(0x00cd);
    for (name, a) in generator_suite(&mut rng) {
        let path = tmp_path(&format!("gen_{name}"));
        write_csr(&path, &a).expect("write");
        let d = 5;
        let b = DenseMatrix::random(a.ncols, d, &mut rng);
        let whole = csr_result(&a, &b, d, 2);
        for budget in budget_ladder(&a) {
            let ooc = OocCsr::open(&path, budget).expect("ooc open");
            assert_eq!((ooc.nrows(), ooc.ncols(), ooc.nnz()), (a.nrows, a.ncols, a.nnz()));
            let got = ooc_result(ooc, &b, d, 2, 1);
            assert_eq!(got, whole, "{name}: file-backed OOC ≠ CSR (budget={budget})");
        }
        let _ = std::fs::remove_file(&path);
    }
}

/// File-backed bands from a **symmetric** banner: the band loader must
/// replay `Coo::symmetrize`'s ordering (stored entries first, mirrors
/// after) or duplicate summation drifts by an ulp.
#[test]
fn file_backed_symmetric_matches_in_memory_bitwise() {
    let mut rng = Prng::new(0x00ce);
    for (name, a) in generator_suite(&mut rng) {
        // lower triangle mirrored — numerically symmetric by construction
        let mut lt = Coo::new(a.nrows, a.nrows);
        for r in 0..a.nrows {
            for (c, v) in a.row_cols(r).iter().zip(a.row_vals(r)) {
                if (*c as usize) <= r {
                    lt.push(r, *c as usize, *v);
                }
            }
        }
        let sym = Csr::from_coo(lt.symmetrize());
        let path = tmp_path(&format!("sym_{name}"));
        write_csr_symmetric(&path, &sym).expect("write symmetric");
        let d = 6;
        let b = DenseMatrix::random(sym.ncols, d, &mut rng);
        let whole = csr_result(&sym, &b, 2, 2);
        for budget in budget_ladder(&sym) {
            let ooc = OocCsr::open(&path, budget).expect("ooc open");
            let got = ooc_result(ooc, &b, 2, 2, 1);
            assert_eq!(got, whole, "{name}: symmetric file OOC ≠ CSR (budget={budget})");
        }
        let _ = std::fs::remove_file(&path);
    }
}

/// Adversarial geometry: a hub row heavier than the budget (gets its
/// own band), empty rows (bands must still cover them and zero their
/// `C` rows), and a run of single-row bands.
#[test]
fn adversarial_hub_and_empty_rows() {
    let n = 24;
    let mut rng = Prng::new(0x00cf);
    let mut coo = Coo::new(n, n);
    for c in 0..n {
        coo.push(0, c, rng.range_f64(-1.0, 1.0)); // hub row
    }
    for r in 2..n {
        if r % 3 != 0 {
            // rows 3, 6, 9, ... stay empty (row 1 too)
            coo.push(r, (r * 5) % n, rng.range_f64(-1.0, 1.0));
            coo.push(r, (r * 7 + 1) % n, rng.range_f64(-1.0, 1.0));
        }
    }
    let a = Csr::from_coo(coo.sorted_dedup());
    let d = 4;
    let b = DenseMatrix::random(n, d, &mut rng);
    let whole = csr_result(&a, &b, d, 2);
    // hub row alone busts this budget; plan_row_bands must give it its
    // own band rather than splitting it
    let hub_budget = band_bytes(1, n) - 1;
    for budget in [0usize, hub_budget, band_bytes(n, a.nnz()) / 3, usize::MAX] {
        let ooc = OocCsr::from_csr(a.clone(), budget);
        let covered: usize = (0..ooc.n_bands()).map(|k| ooc.band_rows(k).len()).sum();
        assert_eq!(covered, n, "bands cover every row incl. empty ones");
        let got = ooc_result(ooc, &b, d, 2, 1);
        assert_eq!(got, whole, "adversarial geometry ≠ CSR (budget={budget})");
    }
    // stale C over the empty rows must have been zeroed
    let zero_rows: Vec<usize> = (0..n).filter(|&r| a.row_cols(r).is_empty()).collect();
    assert!(!zero_rows.is_empty(), "fixture must contain empty rows");
    for r in zero_rows {
        assert!(whole[r * d..(r + 1) * d].iter().all(|&x| x == 0.0));
    }
}

/// An entirely empty matrix still executes and zeroes `C`.
#[test]
fn empty_matrix_zeroes_c() {
    let a = Csr::from_coo(Coo::new(5, 4));
    let b = DenseMatrix::random(4, 3, &mut Prng::new(0x00d0));
    for budget in [0usize, usize::MAX] {
        let kern = OocSpmm::new(OocCsr::from_csr(a.clone(), budget), 2);
        let mut c = DenseMatrix::from_vec(5, 3, vec![5.0; 15]);
        kern.execute(&b, &mut c).expect("empty execute");
        assert!(c.data.iter().all(|&x| x == 0.0), "budget={budget}");
    }
}

/// Randomized: shape, density, budget, dt, threads all drawn per case
/// (PROP_SEED varies the corpus in CI).
#[test]
fn prop_ooc_random_budgets_bitwise() {
    check_default(0x00d1, |rng| {
        let nr = 4 + rng.below_usize(100);
        let nc = 4 + rng.below_usize(100);
        let a = erdos_renyi(nr, nc, rng.range_f64(0.0, 7.0), rng);
        let d = 1 + rng.below_usize(12);
        let dt = 1 + rng.below_usize(d + 3);
        let threads = 1 + rng.below_usize(4);
        let budget = match rng.below_usize(3) {
            0 => 0,
            1 => usize::MAX,
            _ => rng.below_usize(band_bytes(nr, a.nnz()) + 1),
        };
        let b = DenseMatrix::random(nc, d, rng);
        let whole = csr_result(&a, &b, dt, threads);
        let got = ooc_result(OocCsr::from_csr(a.clone(), budget), &b, dt, threads, 1);
        if got != whole {
            return Err(format!(
                "OOC ≠ CSR: {nr}x{nc} nnz={} d={d} dt={dt} threads={threads} budget={budget}",
                a.nnz()
            ));
        }
        Ok(())
    });
}
