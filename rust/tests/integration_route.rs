//! Integration: the structure-adaptive autotuning router — explore,
//! pin, serve from cache, record. Machine parameters are injected and
//! matrices are tiny, so these tests check the *loop's bookkeeping*
//! (decisions, pinning, cache reuse, artifact schema); the performance
//! claim itself is `bench_route`'s job.

use spmm_roofline::coordinator::{AutotunePolicy, Engine, EngineConfig, JobSpec};
use spmm_roofline::gen::{representative_suite, Prng, SparsityClass};
use spmm_roofline::model::MachineParams;
use spmm_roofline::report::{PerfLog, PerfRecord};
use spmm_roofline::sparse::reorder::{permute_symmetric, random_permutation};
use spmm_roofline::sparse::Reordering;
use spmm_roofline::spmm::Impl;

fn router_engine() -> Engine {
    Engine::new(EngineConfig {
        threads: 2,
        machine: Some(MachineParams { beta_gbs: 10.0, pi_gflops: 100.0 }),
        iters: 1,
        warmup: 0,
        impls: vec![Impl::Csr, Impl::Opt, Impl::Csb],
        artifacts_dir: None,
        autotune: AutotunePolicy {
            explore_iters: 1,
            explore_min_secs: 0.0,
            ..AutotunePolicy::enabled()
        },
    })
    .unwrap()
}

/// Register one proxy per sparsity class plus a scrambled mesh (the
/// reordering showcase). Returns the registered names.
fn register_suite(e: &mut Engine, scale: f64) -> Vec<String> {
    for proxy in representative_suite() {
        e.register(proxy.name, proxy.generate(scale)).unwrap();
    }
    let mut rng = Prng::new(0x0de7);
    let mesh = representative_suite()
        .into_iter()
        .find(|p| p.class == SparsityClass::Blocked)
        .unwrap()
        .generate(scale);
    let scrambled = permute_symmetric(&mesh, &random_permutation(mesh.nrows, &mut rng));
    e.register("road_scrambled", scrambled).unwrap();
    e.registry().names().iter().map(|s| s.to_string()).collect()
}

#[test]
fn router_pins_per_matrix_decisions_across_all_classes() {
    let mut e = router_engine();
    let names = register_suite(&mut e, 0.03);
    assert_eq!(names.len(), 5);
    // the generated suite spans all four sparsity classes at
    // registration (tuning may later move individual matrices between
    // classes by reordering — that is the router's lever, not a bug)
    let classes: std::collections::HashSet<SparsityClass> = names
        .iter()
        .map(|n| e.registry().get(n).unwrap().classification.class)
        .collect();
    assert_eq!(classes.len(), 4, "suite must span all four classes");
    let jobs: Vec<JobSpec> = names
        .iter()
        .flat_map(|n| [4usize, 16].map(|d| JobSpec::new(n.clone(), d)))
        .collect();

    let tuned = e.submit_batch(&jobs).unwrap();
    assert_eq!(tuned.n_jobs(), 10);
    // one decision per (matrix, d), every one explored and measured
    let decisions = e.autotuner().decisions();
    assert_eq!(decisions.len(), 10);
    assert_eq!(tuned.routes.len(), 10);
    for dec in &decisions {
        assert!(dec.measured_gflops > 0.0, "{}: no measurement", dec.matrix);
        assert!(dec.predicted_gflops > 0.0);
        assert!(dec.explored >= 1 && dec.explored <= 3);
        assert!(dec.regret_gflops >= 0.0);
    }
    // each matrix's first decision explored the full impl × reordering
    // cross-product; later widths explore formats on the frozen layout
    assert_eq!(
        decisions.iter().filter(|d| d.enumerated >= 9).count(),
        5,
        "one full-space tune per matrix"
    );
    // jobs executed on their pinned decision
    for rec in &tuned.records {
        let dec = e.autotuner().decision(&rec.matrix, rec.d).unwrap();
        assert_eq!(rec.chosen, dec.im, "{} d={}", rec.matrix, rec.d);
        assert_eq!(rec.reorder, dec.reorder);
    }
}

#[test]
fn resubmission_explores_nothing_and_replans_nothing() {
    let mut e = router_engine();
    let names = register_suite(&mut e, 0.03);
    let jobs: Vec<JobSpec> =
        names.iter().map(|n| JobSpec::new(n.clone(), 8)).collect();
    let tuned = e.submit_batch(&jobs).unwrap();
    assert!(tuned.explore_measurements >= jobs.len(), "every job tunes once");
    let warm = e.submit_batch(&jobs).unwrap();
    assert_eq!(warm.explore_measurements, 0, "decisions are pinned");
    assert_eq!(warm.schedule_misses, 0, "schedules all cached");
    assert!(warm.schedule_hit_rate() > 0.99);
    // decisions unchanged
    let again = e.submit_batch(&jobs).unwrap();
    for (a, b) in warm.routes.iter().zip(&again.routes) {
        assert_eq!(a.im, b.im);
        assert_eq!(a.reorder, b.reorder);
    }
}

#[test]
fn routed_batch_total_is_tracked_against_csr_baseline() {
    let mut e = router_engine();
    let names = register_suite(&mut e, 0.03);
    let jobs: Vec<JobSpec> =
        names.iter().map(|n| JobSpec::new(n.clone(), 16)).collect();
    e.submit_batch(&jobs).unwrap(); // tune
    let routed = e.submit_batch(&jobs).unwrap();
    let csr_jobs: Vec<JobSpec> =
        jobs.iter().map(|j| j.clone().with_impl(Impl::Csr)).collect();
    let baseline = e.submit_batch(&csr_jobs).unwrap();
    // at this scale timing noise swamps real differences — assert the
    // comparison is *well-formed*; bench_route enforces the ≥ claim
    assert!(routed.aggregate_gflops() > 0.0);
    assert!(baseline.aggregate_gflops() > 0.0);
    assert!(baseline.records.iter().all(|r| r.chosen == Impl::Csr));
    // forced jobs bypass the router: the baseline batch reports no
    // routed decisions and explores nothing
    assert!(baseline.routes.is_empty());
    assert_eq!(baseline.explore_measurements, 0);
}

#[test]
fn route_artifact_records_choice_prediction_and_measurement() {
    let mut e = router_engine();
    register_suite(&mut e, 0.03);
    for name in ["road_scrambled", "er_18_1"] {
        e.tune(name, 8).unwrap();
    }
    // build the artifact exactly as the route command does
    let mut log = PerfLog::new();
    for dec in e.autotuner().decisions() {
        log.push(PerfRecord {
            reorder: dec.reorder.to_string(),
            predicted_gflops: dec.predicted_gflops,
            ..PerfRecord::basic(
                "bench_route",
                dec.matrix.clone(),
                dec.class.to_string(),
                dec.im.to_string(),
                dec.d,
                dec.dt.min(dec.d),
                dec.measured_gflops,
            )
        });
    }
    let dir = std::env::temp_dir().join("spmm_roofline_route_artifact");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_route.json");
    let path = path.to_str().unwrap();
    let _ = std::fs::remove_file(path);
    log.merge_save(path).unwrap();
    let back = PerfLog::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    assert_eq!(back.records.len(), 2);
    for r in &back.records {
        assert_eq!(r.bench, "bench_route");
        assert!(["none", "rcm", "degree"].contains(&r.reorder.as_str()), "{}", r.reorder);
        assert!(r.predicted_gflops > 0.0, "prediction must be recorded");
        assert!(r.gflops > 0.0, "measurement must be recorded");
    }
    let _ = std::fs::remove_file(path);
}

#[test]
fn scrambled_mesh_layouts_are_genuinely_candidates() {
    // The scrambled mesh classifies as Random/ScaleFree-ish at tiny
    // scale; what matters is that the tuner *enumerated* reordered
    // layouts for it and pinned a consistent winner.
    let mut e = router_engine();
    register_suite(&mut e, 0.03);
    let dec = e.tune("road_scrambled", 16).unwrap();
    assert!(dec.enumerated >= 9, "3 impls × 3 reorderings expected, got {}", dec.enumerated);
    let entry = e.registry().get("road_scrambled").unwrap();
    assert_eq!(entry.reordering(), dec.reorder);
    if dec.reorder != Reordering::None {
        // conversion really happened: permutation recorded, base kept
        assert!(entry.permutation().is_some());
        assert_eq!(entry.base_csr().nnz(), entry.nnz());
    }
    // follow-up submission uses the pinned layout without re-tuning
    let n = e.autotuner().measurements();
    let rec = e.submit(&JobSpec::new("road_scrambled", 16)).unwrap();
    assert_eq!(e.autotuner().measurements(), n);
    assert_eq!(rec.reorder, dec.reorder);
}
