//! Property tests over the SpGEMM kernels, backed by the shared
//! differential oracle ([`spmm_roofline::testutil::dense_spgemm`]):
//!
//! * both kernels vs the dense oracle (tolerance — the oracle
//!   accumulates over every `k`, including absent entries, so its
//!   floating-point sequence legitimately differs), and
//! * both kernels vs each other **bit for bit** — hash/dense
//!   accumulators and the PB merge all add each `C[i, j]`'s
//!   contributions in ascending-`k` order (`spgemm/mod.rs` module
//!   docs), so their structures and values must be identical —
//!
//! across every structural generator (banded, blocked/mesh,
//! Erdős–Rényi, R-MAT, scale-free) × thread counts {1, 4} ×
//! adversarial one-row-per-partition schedules, with the output
//! invariants (sorted, deduplicated, `validate()` passes) checked on
//! every product.

use spmm_roofline::gen::{
    banded, chung_lu, erdos_renyi, mesh2d, rmat, ChungLuParams, MeshKind, Prng,
};
use spmm_roofline::sparse::Csr;
use spmm_roofline::spgemm::{HashSpGemm, PbMergeSpGemm, SpGemm};
use spmm_roofline::spmm::Schedule;
use spmm_roofline::testutil::{assert_csr_eq, check_default, close_slice, dense_spgemm};

/// One matrix per structural regime, sized for test speed.
fn generator_suite(rng: &mut Prng) -> Vec<(&'static str, Csr)> {
    vec![
        ("banded", banded(140, 6, 0.4, rng)),
        ("blocked", mesh2d(12, MeshKind::Triangular, 0.9, rng)),
        ("er", erdos_renyi(150, 150, 5.0, rng)),
        ("rmat", rmat(7, 5.0, 0.57, 0.19, 0.19, rng)),
        (
            "scalefree",
            chung_lu(ChungLuParams { n: 180, alpha: 2.2, avg_deg: 6.0, k_min: 2.0 }, rng),
        ),
    ]
}

/// Structural invariants every SpGEMM output must satisfy: valid CSR
/// (which enforces strictly ascending — i.e. sorted *and*
/// deduplicated — columns per row) with the product shape.
fn check_invariants(c: &Csr, a: &Csr, b: &Csr, what: &str) {
    assert_eq!((c.nrows, c.ncols), (a.nrows, b.ncols), "{what}: shape");
    c.validate().unwrap_or_else(|e| panic!("{what}: invalid product CSR: {e}"));
}

/// The acceptance grid: every generator × A·A and A·Aᵀ-shaped pairs ×
/// threads {1, 4}, both kernels vs the dense oracle and vs each other
/// bitwise.
#[test]
fn spgemm_kernels_match_oracle_and_each_other_across_generators() {
    let mut rng = Prng::new(0xa90);
    for (name, a) in generator_suite(&mut rng) {
        // self-product plus a second structurally-distinct right
        // operand of matching inner dimension
        let b2 = erdos_renyi(a.ncols, 90, 4.0, &mut rng);
        let pairs: Vec<(&str, &Csr, &Csr)> =
            vec![("A·A", &a, &a), ("A·B", &a, &b2)];
        for (pname, pa, pb) in pairs {
            let oracle = dense_spgemm(pa, pb);
            for threads in [1usize, 4] {
                let hash = HashSpGemm::new((*pa).clone(), threads);
                let merge = PbMergeSpGemm::from_csr(pa, threads);
                let c_hash = hash.execute(pb).unwrap();
                let c_merge = merge.execute(pb).unwrap();
                let what = format!("{name} {pname} threads={threads}");
                check_invariants(&c_hash, pa, pb, &format!("{what} HASH"));
                check_invariants(&c_merge, pa, pb, &format!("{what} PBMERGE"));
                // vs the dense oracle, via dense rendering (tolerance)
                close_slice(&c_hash.to_dense(), &oracle, 1e-10, &format!("{what} HASH"))
                    .unwrap();
                // vs each other: bitwise (same accumulation order)
                assert_csr_eq(&c_merge, &c_hash, 0.0);
            }
        }
    }
}

/// Adversarial schedules: one row per partition, so every PB-merge
/// bucket straddles partition boundaries and the hash kernel's slab
/// assembly sees maximal fragmentation — across every generator.
#[test]
fn spgemm_one_row_per_partition_schedules() {
    let mut rng = Prng::new(0xa91);
    let suite: Vec<(&'static str, Csr)> = vec![
        ("banded", banded(24, 3, 0.5, &mut rng)),
        ("blocked", mesh2d(5, MeshKind::Triangular, 0.9, &mut rng)),
        ("er", erdos_renyi(30, 30, 4.0, &mut rng)),
        ("rmat", rmat(5, 4.0, 0.57, 0.19, 0.19, &mut rng)),
        (
            "scalefree",
            chung_lu(ChungLuParams { n: 40, alpha: 2.2, avg_deg: 5.0, k_min: 1.5 }, &mut rng),
        ),
    ];
    for (name, a) in suite {
        let b = erdos_renyi(a.ncols, a.ncols, 4.0, &mut rng);
        let oracle = dense_spgemm(&a, &b);
        // uniform(n, ⌈n/8⌉) degenerates to one row per partition
        let s = Schedule::uniform(a.nrows, a.nrows.div_ceil(8));
        assert_eq!(s.n_parts(), a.nrows, "{name}: want 1-row partitions");
        let hash = HashSpGemm::new(a.clone(), 2);
        let merge = PbMergeSpGemm::from_csr_with_bands(&a, 4, 3, 2);
        let c_hash = hash.execute_with(&b, &s).unwrap();
        let c_merge = merge.execute_with(&b, &s).unwrap();
        check_invariants(&c_hash, &a, &b, name);
        check_invariants(&c_merge, &a, &b, name);
        close_slice(&c_hash.to_dense(), &oracle, 1e-10, name).unwrap();
        assert_csr_eq(&c_merge, &c_hash, 0.0);
    }
}

#[test]
fn prop_spgemm_random_shapes_bands_and_threads() {
    check_default(0xa92, |rng| {
        let m = 4 + rng.below_usize(60);
        let p = 4 + rng.below_usize(60);
        let n = 4 + rng.below_usize(60);
        let a = erdos_renyi(m, p, rng.range_f64(0.0, 6.0), rng);
        let b = erdos_renyi(p, n, rng.range_f64(0.0, 6.0), rng);
        let threads = 1 + rng.below_usize(4);
        let col_band = 1 + rng.below_usize(20);
        let row_band = 1 + rng.below_usize(20);
        let oracle = dense_spgemm(&a, &b);
        let hash = HashSpGemm::new(a.clone(), threads);
        let merge = PbMergeSpGemm::from_csr_with_bands(&a, col_band, row_band, threads);
        let c_hash = hash.execute(&b).map_err(|e| e.to_string())?;
        let c_merge = merge.execute(&b).map_err(|e| e.to_string())?;
        c_hash.validate().map_err(|e| format!("HASH invalid: {e}"))?;
        c_merge.validate().map_err(|e| format!("PBMERGE invalid: {e}"))?;
        let what = format!("{m}x{p}x{n} threads={threads} bands={col_band}/{row_band}");
        close_slice(&c_hash.to_dense(), &oracle, 1e-10, &format!("HASH {what}"))?;
        spmm_roofline::testutil::csr_eq(&c_merge, &c_hash, 0.0, &format!("PBMERGE {what}"))?;
        Ok(())
    });
}

/// The compression factor measured on real products behaves: ≥ 2, and
/// `cf · nnz(C) == flops` exactly when the product is nonempty.
#[test]
fn prop_spgemm_flops_and_compression_factor() {
    use spmm_roofline::spgemm::{compression_factor, spgemm_flops};
    check_default(0xa93, |rng| {
        let n = 8 + rng.below_usize(80);
        let a = erdos_renyi(n, n, rng.range_f64(0.5, 6.0), rng);
        let b = erdos_renyi(n, n, rng.range_f64(0.5, 6.0), rng);
        let flops = spgemm_flops(&a, &b);
        let c = HashSpGemm::new(a.clone(), 2).execute(&b).map_err(|e| e.to_string())?;
        let cf = compression_factor(flops, c.nnz());
        if cf < 2.0 {
            return Err(format!("cf {cf} below the floor"));
        }
        if c.nnz() > 0 && (cf * c.nnz() as f64 - flops).abs() > 1e-6 {
            return Err(format!("cf·nnz(C) = {} != flops {flops}", cf * c.nnz() as f64));
        }
        Ok(())
    });
}
