//! Integration: the XLA/PJRT request path against the native kernels.
//!
//! These tests need `make artifacts` to have run (the Makefile's
//! `test` target guarantees it); they skip gracefully when the
//! artifacts are absent so `cargo test` alone stays green. The whole
//! file needs the real PJRT client (and the `xla` crate), so it only
//! compiles under `--features xla`.
#![cfg(feature = "xla")]

use spmm_roofline::gen::{erdos_renyi, Prng};
use spmm_roofline::runtime::{ArtifactKind, ArtifactManifest, XlaRuntime, XlaSpmm};
use spmm_roofline::sparse::{Coo, Csr};
use spmm_roofline::spmm::{reference_spmm, DenseMatrix, Impl, Spmm};

fn manifest() -> Option<ArtifactManifest> {
    ArtifactManifest::load("artifacts").ok()
}

fn truncate_rows(a: &Csr, width: usize) -> Csr {
    let mut coo = Coo::with_capacity(a.nrows, a.ncols, a.nnz());
    for r in 0..a.nrows {
        for (k, (c, v)) in a.row_cols(r).iter().zip(a.row_vals(r)).enumerate() {
            if k >= width {
                break;
            }
            coo.push(r, *c as usize, *v);
        }
    }
    Csr::from_coo(coo)
}

#[test]
fn xla_spmm_matches_reference() {
    let Some(manifest) = manifest() else {
        eprintln!("skipped: artifacts/ missing (run `make artifacts`)");
        return;
    };
    let spec = manifest
        .find_ell(4096, 8, 16)
        .expect("small artifact missing from manifest");
    let rt = XlaRuntime::cpu().unwrap();
    let mut rng = Prng::new(0x7E57);
    let a = truncate_rows(&erdos_renyi(4096, 4096, 5.0, &mut rng), 8);
    let xla = XlaSpmm::from_csr(&rt, spec, &a).unwrap();
    assert_eq!(xla.id(), Impl::Xla);
    assert_eq!(xla.nnz(), a.nnz());

    let b = DenseMatrix::random(4096, 16, &mut rng);
    let want = reference_spmm(&a, &b);
    let mut c = DenseMatrix::zeros(4096, 16);
    xla.execute(&b, &mut c).unwrap();
    let diff = c.max_abs_diff(&want);
    assert!(diff < 1e-11, "XLA result off by {diff}");

    // idempotent across calls (PJRT buffers not aliased)
    let mut c2 = DenseMatrix::zeros(4096, 16);
    xla.execute(&b, &mut c2).unwrap();
    assert_eq!(c.data, c2.data);
}

#[test]
fn xla_rejects_shape_mismatches() {
    let Some(manifest) = manifest() else {
        eprintln!("skipped: artifacts/ missing");
        return;
    };
    let spec = manifest.find_ell(4096, 8, 16).unwrap();
    let rt = XlaRuntime::cpu().unwrap();
    let mut rng = Prng::new(1);
    // wrong n
    let a = erdos_renyi(100, 100, 2.0, &mut rng);
    assert!(XlaSpmm::from_csr(&rt, spec, &a).is_err());
    // too-wide rows
    let a = erdos_renyi(4096, 4096, 40.0, &mut rng);
    if a.max_row_len() > 8 {
        assert!(XlaSpmm::from_csr(&rt, spec, &a).is_err());
    }
    // wrong d at execute time
    let a = truncate_rows(&erdos_renyi(4096, 4096, 4.0, &mut rng), 8);
    let xla = XlaSpmm::from_csr(&rt, spec, &a).unwrap();
    let b = DenseMatrix::zeros(4096, 8); // artifact wants d=16
    let mut c = DenseMatrix::zeros(4096, 8);
    assert!(xla.execute(&b, &mut c).is_err());
}

#[test]
fn manifest_lists_full_artifact_set() {
    let Some(manifest) = manifest() else {
        eprintln!("skipped: artifacts/ missing");
        return;
    };
    // the aot.py "full" set: 5 ELL + 1 GCN
    assert!(manifest.of_kind(ArtifactKind::EllSpmm).count() >= 5);
    assert!(manifest.of_kind(ArtifactKind::GcnLayer).count() >= 1);
    for d in [1usize, 4, 16, 64] {
        assert!(
            manifest.find_ell(16384, 16, d).is_some(),
            "missing ell_spmm_n16384_w16_d{d}"
        );
    }
}

#[test]
fn gcn_artifact_executes_and_matches_native_composition() {
    let Some(manifest) = manifest() else {
        eprintln!("skipped: artifacts/ missing");
        return;
    };
    let Some(spec) = manifest
        .of_kind(ArtifactKind::GcnLayer)
        .find(|a| a.n == 4096)
    else {
        eprintln!("skipped: no gcn artifact");
        return;
    };
    let rt = XlaRuntime::cpu().unwrap();
    let module = rt.compile_hlo_file(&spec.path).unwrap();

    let mut rng = Prng::new(0x6C9);
    let a = truncate_rows(&erdos_renyi(4096, 4096, 5.0, &mut rng), spec.width);
    let ell = spmm_roofline::sparse::Ell::from_csr_with_width(&a, spec.width);
    let b = DenseMatrix::random(4096, spec.d, &mut rng);
    let dout = spec.dout.unwrap();
    let w = DenseMatrix::random(spec.d, dout, &mut rng);

    // literals
    let cols: Vec<i32> = ell.col_idx.iter().map(|&c| c as i32).collect();
    let cols_lit = xla::Literal::vec1(&cols).reshape(&[4096, spec.width as i64]).unwrap();
    let vals_lit = xla::Literal::vec1(&ell.vals).reshape(&[4096, spec.width as i64]).unwrap();
    let b_lit = xla::Literal::vec1(&b.data).reshape(&[4096, spec.d as i64]).unwrap();
    let w_lit = xla::Literal::vec1(&w.data).reshape(&[spec.d as i64, dout as i64]).unwrap();
    let out = module.execute1(&[&cols_lit, &vals_lit, &b_lit, &w_lit]).unwrap();
    let got = out.to_vec::<f64>().unwrap();

    // native composition: relu((A·B)·W)
    let spmm = reference_spmm(&a, &b);
    let mut want = vec![0.0f64; 4096 * dout];
    for r in 0..4096 {
        for k in 0..dout {
            let mut acc = 0.0;
            for j in 0..spec.d {
                acc += spmm.get(r, j) * w.get(j, k);
            }
            want[r * dout + k] = acc.max(0.0);
        }
    }
    let max_diff = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(max_diff < 1e-10, "gcn artifact off by {max_diff}");
}

#[test]
fn bell_artifact_matches_native_bsr_composition() {
    let Some(manifest) = manifest() else {
        eprintln!("skipped: artifacts/ missing");
        return;
    };
    let Some(spec) = manifest.of_kind(ArtifactKind::BellSpmm).next() else {
        eprintln!("skipped: no bell artifact (run `make artifacts`)");
        return;
    };
    let bs = spec.bs.expect("bell spec carries bs");
    let (nbr, mb, n, d) = (spec.n / bs, spec.width, spec.n, spec.d);

    // build a block-structured matrix that fits (nbr, mb, bs): place
    // up to mb random dense tiles per block row
    let mut rng = Prng::new(0xBE11);
    let mut bcols = vec![0i32; nbr * mb];
    let mut blocks = vec![0.0f64; nbr * mb * bs * bs];
    let mut dense_a = spmm_roofline::spmm::DenseMatrix::zeros(n, n);
    for i in 0..nbr {
        let n_here = 1 + rng.below_usize(mb);
        let mut used = std::collections::HashSet::new();
        for k in 0..n_here {
            let mut j = rng.below_usize(nbr);
            while !used.insert(j) {
                j = rng.below_usize(nbr);
            }
            bcols[i * mb + k] = j as i32;
            for rr in 0..bs {
                for cc in 0..bs {
                    let v = rng.range_f64(-1.0, 1.0);
                    blocks[((i * mb + k) * bs + rr) * bs + cc] = v;
                    dense_a.set(i * bs + rr, j * bs + cc, v);
                }
            }
        }
    }

    let rt = XlaRuntime::cpu().unwrap();
    let module = rt.compile_hlo_file(&spec.path).unwrap();
    let b = DenseMatrix::random(n, d, &mut rng);
    let bcols_lit = xla::Literal::vec1(&bcols).reshape(&[nbr as i64, mb as i64]).unwrap();
    let blocks_lit = xla::Literal::vec1(&blocks)
        .reshape(&[nbr as i64, mb as i64, bs as i64, bs as i64])
        .unwrap();
    let b_lit = xla::Literal::vec1(&b.data).reshape(&[n as i64, d as i64]).unwrap();
    let out = module.execute1(&[&bcols_lit, &blocks_lit, &b_lit]).unwrap();
    let got = out.to_vec::<f64>().unwrap();

    // reference: dense matmul over the scattered tiles
    for r in 0..n {
        for j in 0..d {
            let mut want = 0.0;
            for k in 0..n {
                let av = dense_a.get(r, k);
                if av != 0.0 {
                    want += av * b.get(k, j);
                }
            }
            let g = got[r * d + j];
            assert!((g - want).abs() < 1e-9, "bell mismatch at ({r},{j}): {g} vs {want}");
        }
    }
}
