//! Differential wall around the MatrixMarket readers: the streaming
//! path ([`MmStream`] / `read_csr_streaming` / `StreamingCsrBuilder`)
//! must match the materializing oracle (`read_coo_from`) **entry for
//! entry and bit for bit** on a fixture corpus covering every
//! supported banner, and every malformed input must come back as a
//! typed `Err` — never a panic — from both paths.

use std::io::{BufReader, Cursor};
use std::path::PathBuf;

use spmm_roofline::error::Error;
use spmm_roofline::gen::{
    banded, chung_lu, erdos_renyi, mesh2d, rmat, ChungLuParams, MeshKind, Prng,
};
use spmm_roofline::sparse::mm_io::{
    band_bytes, read_coo, read_coo_from, read_csr_streaming, read_csr_streaming_from,
    write_csr, write_csr_symmetric, MmStream, MmSymmetry, StreamingCsrBuilder,
};
use spmm_roofline::sparse::{Coo, Csr};
use spmm_roofline::testutil::check_default;

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

/// Every `.mtx` fixture, sorted for deterministic order.
fn fixture_paths() -> Vec<PathBuf> {
    let mut v: Vec<PathBuf> = std::fs::read_dir(fixture_dir())
        .expect("tests/fixtures exists")
        .map(|e| e.expect("dir entry").path())
        .filter(|p| p.extension().map(|e| e == "mtx").unwrap_or(false))
        .collect();
    v.sort();
    assert_eq!(v.len(), 5, "fixture corpus: {v:?}");
    v
}

fn tmp_path(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("spmm_roofline_prop_mm_io");
    std::fs::create_dir_all(&dir).expect("temp dir");
    dir.join(format!("{tag}.mtx"))
}

/// One matrix per structural regime (the shared generator suite).
fn generator_suite(rng: &mut Prng) -> Vec<(&'static str, Csr)> {
    vec![
        ("banded", banded(180, 6, 0.4, rng)),
        ("blocked", mesh2d(14, MeshKind::Triangular, 0.9, rng)),
        ("er", erdos_renyi(200, 200, 6.0, rng)),
        ("rmat", rmat(8, 6.0, 0.57, 0.19, 0.19, rng)),
        (
            "scalefree",
            chung_lu(ChungLuParams { n: 250, alpha: 2.2, avg_deg: 8.0, k_min: 2.0 }, rng),
        ),
    ]
}

/// Keep only the lower triangle (diagonal included) of `a`, then
/// mirror — a numerically symmetric matrix for the symmetric-banner
/// round-trip.
fn symmetrized(a: &Csr) -> Csr {
    let mut lt = Coo::new(a.nrows, a.nrows);
    for r in 0..a.nrows {
        for (c, v) in a.row_cols(r).iter().zip(a.row_vals(r)) {
            if (*c as usize) <= r {
                lt.push(r, *c as usize, *v);
            }
        }
    }
    Csr::from_coo(lt.symmetrize())
}

#[test]
fn fixtures_stream_matches_oracle_entry_for_entry() {
    for path in fixture_paths() {
        let name = path.display();
        let oracle = read_coo(&path).unwrap_or_else(|e| panic!("{name}: oracle: {e}"));
        let f = std::fs::File::open(&path).expect("open fixture");
        let mut s = MmStream::open(BufReader::new(f))
            .unwrap_or_else(|e| panic!("{name}: stream open: {e}"));
        let h = s.header();
        let mut coo = Coo::with_capacity(h.nrows, h.ncols, h.expanded_nnz());
        while let Some((r, c, v)) =
            s.next_entry().unwrap_or_else(|e| panic!("{name}: stream: {e}"))
        {
            coo.push(r, c, v);
        }
        assert_eq!(s.entries_read(), h.nnz, "{name}: declared count honoured");
        if h.symmetry == MmSymmetry::Symmetric {
            coo = coo.symmetrize();
        }
        // pre-dedup triple arrays identical: same entries, same order
        assert_eq!(coo.rows, oracle.rows, "{name}: row stream");
        assert_eq!(coo.cols, oracle.cols, "{name}: col stream");
        assert_eq!(coo.vals, oracle.vals, "{name}: val stream (bitwise)");
        assert_eq!((coo.nrows, coo.ncols), (oracle.nrows, oracle.ncols), "{name}: shape");
    }
}

#[test]
fn fixtures_streaming_csr_is_bitwise_oracle() {
    for path in fixture_paths() {
        let name = path.display();
        let oracle = Csr::from_coo(read_coo(&path).expect("oracle read"));
        let streamed = read_csr_streaming(&path).expect("streaming read");
        assert_eq!(streamed, oracle, "{name}: streaming CSR ≠ oracle CSR");
    }
}

#[test]
fn fixtures_builder_bands_concatenate_to_oracle() {
    for path in fixture_paths() {
        let name = path.display();
        let oracle_coo = read_coo(&path).expect("oracle read");
        let whole = Csr::from_coo(oracle_coo.clone());
        let budgets =
            [0usize, band_bytes(whole.nrows, whole.nnz()) / 2, usize::MAX];
        for budget in budgets {
            let mut b = StreamingCsrBuilder::with_capacity(
                whole.nrows,
                whole.ncols,
                budget,
                oracle_coo.nnz(),
            );
            for ((&r, &c), &v) in
                oracle_coo.rows.iter().zip(&oracle_coo.cols).zip(&oracle_coo.vals)
            {
                b.push(r as usize, c as usize, v).expect("in-range push");
            }
            let bands = b.finish().expect("finish");
            let mut covered = 0usize;
            for band in &bands {
                assert_eq!(band.row_start, covered, "{name}: bands contiguous");
                assert!(band.csr.nrows > 0, "{name}: no empty bands");
                for lr in 0..band.csr.nrows {
                    let gr = band.row_start + lr;
                    assert_eq!(band.csr.row_cols(lr), whole.row_cols(gr), "{name} row {gr}");
                    assert_eq!(
                        band.csr.row_vals(lr),
                        whole.row_vals(gr),
                        "{name} row {gr} bitwise (budget {budget})"
                    );
                }
                covered += band.csr.nrows;
            }
            assert_eq!(covered, whole.nrows, "{name}: bands cover all rows");
        }
    }
}

#[test]
fn generators_roundtrip_general_banner_bitwise() {
    let mut rng = Prng::new(0x310);
    for (name, a) in generator_suite(&mut rng) {
        let path = tmp_path(&format!("gen_{name}"));
        write_csr(&path, &a).expect("write");
        let oracle = Csr::from_coo(read_coo(&path).expect("oracle read"));
        let streamed = read_csr_streaming(&path).expect("streaming read");
        assert_eq!(oracle, a, "{name}: write → oracle read must round-trip bitwise");
        assert_eq!(streamed, a, "{name}: write → streaming read must round-trip bitwise");
    }
}

#[test]
fn generators_roundtrip_symmetric_banner_bitwise() {
    let mut rng = Prng::new(0x311);
    for (name, a) in generator_suite(&mut rng) {
        let sym = symmetrized(&a);
        let path = tmp_path(&format!("sym_{name}"));
        write_csr_symmetric(&path, &sym).expect("write symmetric");
        let oracle = Csr::from_coo(read_coo(&path).expect("oracle read"));
        let streamed = read_csr_streaming(&path).expect("streaming read");
        assert_eq!(oracle, sym, "{name}: symmetric write → oracle read round-trip");
        assert_eq!(streamed, oracle, "{name}: streaming ≠ oracle on symmetric file");
    }
}

#[test]
fn malformed_inputs_are_typed_errors_on_both_paths() {
    let overflow = format!(
        "%%MatrixMarket matrix coordinate real general\n4 4 {}\n",
        u32::MAX as u64 + 1
    );
    let sym_overflow = format!(
        "%%MatrixMarket matrix coordinate real symmetric\n4 4 {}\n",
        u32::MAX / 2 + 1
    );
    let huge_dim = format!(
        "%%MatrixMarket matrix coordinate real general\n{} 4 1\n1 1 1.0\n",
        u32::MAX as u64 + 1
    );
    let cases: Vec<(&str, String)> = vec![
        ("empty file", String::new()),
        ("not a banner", "3 3 1\n1 1 1.0\n".into()),
        ("array banner", "%%MatrixMarket matrix array real general\n2 2\n1.0\n".into()),
        ("bad field", "%%MatrixMarket matrix coordinate complex general\n2 2 1\n1 1 1.0 0.0\n".into()),
        ("bad symmetry", "%%MatrixMarket matrix coordinate real hermitian\n2 2 1\n1 1 1.0\n".into()),
        ("missing size line", "%%MatrixMarket matrix coordinate real general\n% only comments\n".into()),
        ("short size line", "%%MatrixMarket matrix coordinate real general\n2 2\n1 1 1.0\n".into()),
        ("non-numeric size", "%%MatrixMarket matrix coordinate real general\n2 2 x\n1 1 1.0\n".into()),
        ("truncated body", "%%MatrixMarket matrix coordinate real general\n3 3 3\n1 1 1.0\n".into()),
        ("extra entries", "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 1.0\n2 2 2.0\n".into()),
        ("zero-based row", "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n".into()),
        ("zero-based col", "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 0 1.0\n".into()),
        ("row past nrows", "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n".into()),
        ("col past ncols", "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 3 1.0\n".into()),
        ("missing value", "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1\n".into()),
        ("non-numeric value", "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 abc\n".into()),
        ("inf value", "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 inf\n".into()),
        ("neg-inf value", "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 -inf\n".into()),
        ("nan value", "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 nan\n".into()),
        ("non-numeric row", "%%MatrixMarket matrix coordinate real general\n2 2 1\nx 1 1.0\n".into()),
        ("nnz overflows u32", overflow),
        ("symmetric nnz overflows after mirroring", sym_overflow),
        ("dimension overflows u32", huge_dim),
        ("non-square symmetric", "%%MatrixMarket matrix coordinate real symmetric\n2 3 1\n1 1 1.0\n".into()),
    ];
    for (label, text) in &cases {
        match read_coo_from(Cursor::new(text.clone())) {
            Err(Error::Parse(msg)) => assert!(!msg.is_empty(), "{label}: empty oracle message"),
            Err(e) => panic!("{label}: oracle returned non-Parse error {e}"),
            Ok(_) => panic!("{label}: oracle accepted malformed input"),
        }
        match read_csr_streaming_from(Cursor::new(text.clone())) {
            Err(Error::Parse(msg)) => {
                assert!(!msg.is_empty(), "{label}: empty streaming message")
            }
            Err(e) => panic!("{label}: streaming returned non-Parse error {e}"),
            Ok(_) => panic!("{label}: streaming accepted malformed input"),
        }
    }
}

#[test]
fn stream_fuses_after_error() {
    let text = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n9 9 1.0\n";
    let mut s = MmStream::open(Cursor::new(text)).unwrap();
    assert!(s.next_entry().unwrap().is_some());
    assert!(s.next_entry().is_err(), "out-of-range entry errors");
    // fused: no resurrection after the error
    assert!(s.next_entry().unwrap().is_none());
    assert!(s.next().is_none());
}

#[test]
fn prop_write_read_roundtrips_and_bands_match() {
    check_default(0x312, |rng| {
        let nr = 4 + rng.below_usize(60);
        let nc = 4 + rng.below_usize(60);
        let a = erdos_renyi(nr, nc, rng.range_f64(0.5, 6.0), rng);
        let path = tmp_path(&format!("prop_{nr}_{nc}_{}", rng.below_usize(1 << 30)));
        write_csr(&path, &a).map_err(|e| format!("write: {e}"))?;
        let oracle = Csr::from_coo(read_coo(&path).map_err(|e| format!("oracle: {e}"))?);
        let streamed = read_csr_streaming(&path).map_err(|e| format!("stream: {e}"))?;
        if oracle != a {
            return Err("oracle read ≠ written matrix".into());
        }
        if streamed != oracle {
            return Err("streaming read ≠ oracle read".into());
        }
        // random budget: bands must still concatenate to the whole
        let budget = rng.below_usize(band_bytes(a.nrows, a.nnz()) + 1);
        let mut b = StreamingCsrBuilder::new(a.nrows, a.ncols, budget);
        for r in 0..a.nrows {
            for (c, v) in a.row_cols(r).iter().zip(a.row_vals(r)) {
                b.push(r, *c as usize, *v).map_err(|e| format!("push: {e}"))?;
            }
        }
        let bands = b.finish().map_err(|e| format!("finish: {e}"))?;
        let mut covered = 0usize;
        for band in &bands {
            for lr in 0..band.csr.nrows {
                let gr = band.row_start + lr;
                if band.csr.row_vals(lr) != a.row_vals(gr)
                    || band.csr.row_cols(lr) != a.row_cols(gr)
                {
                    return Err(format!("band row {gr} differs (budget {budget})"));
                }
            }
            covered += band.csr.nrows;
        }
        if covered != a.nrows {
            return Err(format!("bands cover {covered} of {} rows", a.nrows));
        }
        let _ = std::fs::remove_file(&path);
        Ok(())
    });
}
