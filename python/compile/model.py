"""Layer-2: the JAX compute graphs that get AOT-lowered for the Rust
runtime.

Two entry points:

* :func:`spmm` — the paper's kernel, padded-ELL SpMM, dispatching to
  the Layer-1 Pallas kernel.
* :func:`gcn_layer` — the applied workload the paper's introduction
  motivates (GNN propagation): ``relu((A @ B) @ W)``, i.e. SpMM feeding
  a dense feature transform. Lowering this whole layer as one module
  lets XLA fuse the SpMM epilogue into the matmul prologue.

Python only ever runs at build time (``make artifacts``); the Rust
coordinator executes the lowered HLO through PJRT.
"""

import jax
import jax.numpy as jnp

from compile.kernels.bell_spmm import bell_spmm
from compile.kernels.ell_spmm import ell_spmm

# The paper stores matrix values in double precision (§III); keep the
# artifacts in f64 so the Rust-native kernels and the XLA path are
# bit-comparable.
jax.config.update("jax_enable_x64", True)


def spmm(cols, vals, b, *, block_rows=None):
    """Padded-ELL SpMM ``C = A @ B`` (Layer-1 Pallas kernel inside)."""
    kwargs = {} if block_rows is None else {"block_rows": block_rows}
    return ell_spmm(cols, vals, b, **kwargs)


def gcn_layer(cols, vals, b, w):
    """One GCN-style propagation layer: ``relu((A @ B) @ W)``."""
    return jnp.maximum(spmm(cols, vals, b) @ w, 0.0)


def bell_entry(block_cols, blocks, b):
    """AOT entry point for blocked-ELL SpMM (the MXU-mapped kernel)."""
    return (bell_spmm(block_cols, blocks, b),)


def spmm_entry(cols, vals, b):
    """AOT entry point for plain SpMM (tuple-returning, see aot.py)."""
    return (spmm(cols, vals, b),)


def gcn_entry(cols, vals, b, w):
    """AOT entry point for the GCN layer."""
    return (gcn_layer(cols, vals, b, w),)
