"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated against these references by
``python/tests/test_kernel.py`` (hypothesis sweeps shapes and dtypes).
The references are deliberately naive: direct gathers and einsums with
no tiling, so their correctness is self-evident.
"""

import jax.numpy as jnp


def ell_spmm_ref(cols, vals, b):
    """Reference padded-ELL SpMM: ``C = A @ B``.

    Args:
      cols: ``(n, w)`` int32 — column index of each slot (padding slots
        may hold any in-range index).
      vals: ``(n, w)`` float — value of each slot (0.0 in padding).
      b: ``(n_cols, d)`` float dense matrix.

    Returns:
      ``(n, d)`` dense result.
    """
    gathered = jnp.take(b, cols, axis=0)  # (n, w, d)
    return jnp.einsum("rw,rwd->rd", vals, gathered)


def gcn_layer_ref(cols, vals, b, w):
    """Reference GCN-style layer: ``relu((A @ B) @ W)``."""
    return jnp.maximum(ell_spmm_ref(cols, vals, b) @ w, 0.0)


def dense_spmm_ref(a_dense, b):
    """Fully dense reference (tiny shapes only)."""
    return a_dense @ b
