"""Layer-1: padded-ELL SpMM as a Pallas kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CSB
kernel tiles the sparse matrix so each cache tile's slice of ``B`` and
``C`` stays resident. On TPU the same insight maps to Pallas
``BlockSpec`` tiling: the grid walks row tiles of the ELL arrays, each
program gathers its tile's ``B`` rows into VMEM and contracts a
``(rows_tile, w) × (rows_tile, w, d)`` product — static shapes
throughout, which is what both XLA AOT and TPU tiling require (and why
the request path uses ELL rather than CSR).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the kernel is lowered to plain HLO ops. TPU
performance is estimated analytically in DESIGN.md §7.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Per-core VMEM budget a real TPU lowering would size tiles against.
VMEM_BUDGET_BYTES = 16 << 20


def choose_block_rows(n, w, d, dtype_bytes=8, budget=VMEM_BUDGET_BYTES):
    """Largest row-tile that fits the VMEM budget (and divides n).

    Tile footprint per grid step (slot-loop kernel): the cols+vals
    tiles (w·12 bytes/row) plus the accumulator and one gathered slice
    (2·d·8 bytes/row). Fewer, larger grid steps also minimise the
    per-step dispatch overhead the interpret/CPU path pays — see
    EXPERIMENTS.md §Perf (29× at n=16384, w=16, d=16).
    """
    per_row = w * (4 + dtype_bytes) + 2 * d * dtype_bytes
    bt = min(n, max(1, budget // per_row))
    # round down to a divisor of n (n is a power of two in our artifacts)
    while n % bt != 0:
        bt -= 1
    return bt


DEFAULT_BLOCK_ROWS = 128


def _ell_spmm_kernel(cols_ref, vals_ref, b_ref, o_ref):
    """One grid step: SpMM for a tile of rows.

    ``cols_ref/vals_ref/o_ref`` are (block_rows, ·) VMEM tiles; ``b_ref``
    is the full dense matrix (gather targets are data-dependent, so B
    cannot be block-partitioned — on a real TPU this is the HBM-resident
    operand the gather streams from).

    The slot loop is unrolled statically (w is a compile-time shape):
    each step gathers one (bt, d) slice of B and multiply-accumulates.
    This avoids materialising the (bt, w, d) gathered tensor that a
    gather+einsum formulation would stage — ~w× less intermediate
    traffic, and on TPU it keeps the VMEM footprint to 2 tiles instead
    of w (measured in EXPERIMENTS.md §Perf as a 3–4× CPU speedup of the
    lowered artifact).
    """
    cols = cols_ref[...]  # (bt, w) int32
    vals = vals_ref[...]  # (bt, w)
    b = b_ref[...]
    w = cols.shape[1]
    acc = jnp.zeros(o_ref.shape, dtype=o_ref.dtype)
    for k in range(w):
        acc = acc + vals[:, k : k + 1] * jnp.take(b, cols[:, k], axis=0)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_rows",))
def ell_spmm(cols, vals, b, *, block_rows=None):
    """Padded-ELL SpMM ``C = A @ B`` via a row-tiled Pallas kernel.

    Args:
      cols: ``(n, w)`` int32 slot column indices (padding: any in-range
        index with a zero value).
      vals: ``(n, w)`` slot values.
      b: ``(n_b, d)`` dense matrix.
      block_rows: rows per grid step (static). ``n`` must be divisible
        by it after clamping to ``n``.

    Returns:
      ``(n, d)`` dense result, same dtype as ``vals``/``b``.
    """
    n, w = cols.shape
    _, d = b.shape
    if block_rows is None:
        block_rows = choose_block_rows(n, w, d)
    bt = min(block_rows, n)
    if n % bt != 0:
        raise ValueError(f"n={n} not divisible by block_rows={bt}")
    grid = (n // bt,)
    return pl.pallas_call(
        _ell_spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, w), lambda i: (i, 0)),
            pl.BlockSpec((bt, w), lambda i: (i, 0)),
            pl.BlockSpec(b.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), vals.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(cols, vals, b)


def vmem_footprint_bytes(n_rows_tile, w, d, n_b, dtype_bytes=8):
    """Analytic VMEM footprint of one grid step (DESIGN.md §7 / §Perf).

    Counts the operand tiles a real TPU lowering would stage in VMEM:
    cols + vals tiles, the accumulator, and one gathered (bt, d) slice
    (the slot loop re-uses the slice buffer; the full B stays in HBM,
    gather-streamed, so it is *not* counted).
    """
    cols_b = n_rows_tile * w * 4
    vals_b = n_rows_tile * w * dtype_bytes
    acc_b = n_rows_tile * d * dtype_bytes
    slice_b = n_rows_tile * d * dtype_bytes
    del n_b
    return cols_b + vals_b + acc_b + slice_b
