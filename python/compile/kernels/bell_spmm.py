"""Layer-1: blocked-ELL SpMM — the MXU mapping of the paper's CSB.

DESIGN.md §Hardware-Adaptation: CSB's cache tiles become *dense*
``bs × bs`` blocks (cuSPARSE's blocked-ELL layout), so the per-block
work is a dense ``(bs, bs) @ (bs, d)`` contraction — exactly what the
TPU MXU (or tensor cores, for the GPU papers the related work targets)
consumes. Padding is two-level: every block row stores ``max_blocks``
block slots (empty slots point at block-column 0 with an all-zero
tile), and blocks pad internally with zeros.

Layout:
  block_cols: (nbr, mb)          int32  — block-column index per slot
  blocks:     (nbr, mb, bs, bs)  float  — dense tiles
  b:          (n, d)             float  — dense operand (n = nbr·bs)
  out:        (n, d)
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bell_kernel(bcols_ref, blocks_ref, b_ref, o_ref):
    """One grid step = one block row: mb dense (bs,bs)@(bs,d) MACs."""
    bcols = bcols_ref[...]  # (1, mb)
    blocks = blocks_ref[...]  # (1, mb, bs, bs)
    b = b_ref[...]  # (n, d)
    _, mb, bs, _ = blocks.shape
    d = b.shape[1]
    acc = jnp.zeros((bs, d), dtype=o_ref.dtype)
    for k in range(mb):  # static unroll over block slots
        col = bcols[0, k]
        tile = blocks[0, k]  # (bs, bs)
        start = (col * bs).astype(jnp.int32)
        rows = jax.lax.dynamic_slice(b, (start, jnp.int32(0)), (bs, d))  # (bs, d)
        acc = acc + tile @ rows  # the MXU contraction
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=())
def bell_spmm(block_cols, blocks, b):
    """Blocked-ELL SpMM ``C = A @ B``.

    Args:
      block_cols: ``(nbr, mb)`` int32 — block-column per slot (padding
        slots: any valid index with an all-zero tile).
      blocks: ``(nbr, mb, bs, bs)`` dense tiles.
      b: ``(n, d)`` with ``n == nbr * bs``.

    Returns:
      ``(n, d)``.
    """
    nbr, mb, bs, bs2 = blocks.shape
    assert bs == bs2, "tiles must be square"
    n, d = b.shape
    if n != nbr * bs:
        raise ValueError(f"b rows {n} != nbr*bs {nbr * bs}")
    return pl.pallas_call(
        _bell_kernel,
        grid=(nbr,),
        in_specs=[
            pl.BlockSpec((1, mb), lambda i: (i, 0)),
            pl.BlockSpec((1, mb, bs, bs), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bs, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), blocks.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(block_cols, blocks, b)


def bell_from_dense(a, bs, mb=None):
    """Build blocked-ELL arrays from a dense matrix (test helper /
    small-matrix path; the Rust side builds the same layout from CSR).

    Returns ``(block_cols, blocks)`` with ``mb`` = max nonzero blocks
    per block row (or the given mb, which must be >= that).
    """
    import numpy as np

    a = np.asarray(a)
    n, m = a.shape
    assert n % bs == 0 and m % bs == 0, "pad the matrix to a multiple of bs first"
    nbr, nbc = n // bs, m // bs
    rows = []
    for i in range(nbr):
        cols_here = []
        for j in range(nbc):
            tile = a[i * bs : (i + 1) * bs, j * bs : (j + 1) * bs]
            if np.any(tile != 0.0):
                cols_here.append(j)
        rows.append(cols_here)
    need = max((len(r) for r in rows), default=0) or 1
    if mb is None:
        mb = need
    assert mb >= need, f"mb={mb} < max blocks/row {need}"
    block_cols = np.zeros((nbr, mb), np.int32)
    blocks = np.zeros((nbr, mb, bs, bs), a.dtype)
    for i, cols_here in enumerate(rows):
        for k, j in enumerate(cols_here):
            block_cols[i, k] = j
            blocks[i, k] = a[i * bs : (i + 1) * bs, j * bs : (j + 1) * bs]
    return jnp.asarray(block_cols), jnp.asarray(blocks)


def bell_ref(block_cols, blocks, b):
    """Pure-jnp oracle: scatter tiles into dense A, then matmul."""
    nbr, mb, bs, _ = blocks.shape
    n = nbr * bs
    a = jnp.zeros((n, b.shape[0]), blocks.dtype)
    for i in range(nbr):
        for k in range(mb):
            j = int(block_cols[i, k])
            a = a.at[i * bs : (i + 1) * bs, j * bs : (j + 1) * bs].add(blocks[i, k])
    return a @ b


def mxu_utilization_estimate(bs, fill_ratio):
    """DESIGN.md §7: fraction of MXU MACs doing useful work for a
    given tile edge and structural fill. The MXU is a 128×128 systolic
    array; a (bs,bs)@(bs,d) issue occupies (bs/128)² of it per pass,
    and `fill_ratio` of the multiplies are structurally nonzero."""
    occupancy = min(bs / 128.0, 1.0) ** 2
    return occupancy * fill_ratio
