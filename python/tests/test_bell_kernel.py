"""Blocked-ELL Pallas kernel vs oracle and dense matmul (hypothesis
over block geometry)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.bell_spmm import (
    bell_from_dense,
    bell_ref,
    bell_spmm,
    mxu_utilization_estimate,
)

jax.config.update("jax_enable_x64", True)


def random_block_matrix(rng, nbr, nbc, bs, block_density):
    """Dense matrix whose nonzeros live in randomly chosen bs×bs blocks."""
    a = np.zeros((nbr * bs, nbc * bs))
    for i in range(nbr):
        for j in range(nbc):
            if rng.uniform() < block_density:
                a[i * bs : (i + 1) * bs, j * bs : (j + 1) * bs] = rng.uniform(
                    -1, 1, size=(bs, bs)
                )
    # guarantee at least one block so mb >= 1 is honest
    a[:bs, :bs] = rng.uniform(-1, 1, size=(bs, bs))
    return a


@settings(max_examples=25, deadline=None)
@given(
    nbr=st.integers(1, 5),
    bs=st.sampled_from([1, 2, 4, 8]),
    d=st.integers(1, 17),
    density=st.floats(0.1, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_dense_matmul(nbr, bs, d, density, seed):
    rng = np.random.default_rng(seed)
    a = random_block_matrix(rng, nbr, nbr, bs, density)
    bcols, blocks = bell_from_dense(a, bs)
    b = jnp.asarray(rng.uniform(-1, 1, size=(nbr * bs, d)))
    got = bell_spmm(bcols, blocks, b)
    want = jnp.asarray(a) @ b
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_matches_ref_with_extra_padding_slots():
    rng = np.random.default_rng(3)
    a = random_block_matrix(rng, 3, 3, 4, 0.5)
    bcols, blocks = bell_from_dense(a, 4, mb=6)  # over-padded
    b = jnp.asarray(rng.uniform(-1, 1, size=(12, 5)))
    got = bell_spmm(bcols, blocks, b)
    np.testing.assert_allclose(got, bell_ref(bcols, blocks, b), rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(got, jnp.asarray(a) @ b, rtol=1e-12, atol=1e-12)


def test_rejects_bad_b_rows():
    rng = np.random.default_rng(4)
    a = random_block_matrix(rng, 2, 2, 4, 0.5)
    bcols, blocks = bell_from_dense(a, 4)
    b = jnp.zeros((9, 3))
    with pytest.raises(ValueError, match="b rows"):
        bell_spmm(bcols, blocks, b)


def test_mxu_estimate_monotone():
    assert mxu_utilization_estimate(128, 1.0) == 1.0
    assert mxu_utilization_estimate(8, 1.0) < 0.01
    assert mxu_utilization_estimate(64, 0.5) < mxu_utilization_estimate(64, 1.0)
