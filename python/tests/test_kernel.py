"""Layer-1 correctness: the Pallas ELL-SpMM kernel vs the pure-jnp
oracle, with hypothesis sweeping shapes, dtypes and padding patterns.

This is the CORE correctness signal for the compile path: everything
the Rust runtime executes flows through this kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ell_spmm import choose_block_rows, ell_spmm, vmem_footprint_bytes
from compile.kernels.ref import dense_spmm_ref, ell_spmm_ref

jax.config.update("jax_enable_x64", True)


def make_ell(rng, n, w, ncols, dtype, pad_fraction=0.3):
    """Random padded-ELL arrays with ~pad_fraction zeroed slots."""
    cols = rng.integers(0, ncols, size=(n, w)).astype(np.int32)
    vals = rng.uniform(-1, 1, size=(n, w)).astype(dtype)
    mask = rng.uniform(size=(n, w)) < pad_fraction
    vals[mask] = 0.0
    return jnp.asarray(cols), jnp.asarray(vals)


@pytest.mark.parametrize("dtype", [np.float64, np.float32])
@pytest.mark.parametrize("n,w,d", [(8, 1, 1), (16, 4, 3), (32, 8, 16), (64, 3, 64)])
def test_matches_reference_grid(dtype, n, w, d):
    rng = np.random.default_rng(42)
    cols, vals = make_ell(rng, n, w, n, dtype)
    b = jnp.asarray(rng.uniform(-1, 1, size=(n, d)).astype(dtype))
    got = ell_spmm(cols, vals, b, block_rows=n)
    want = ell_spmm_ref(cols, vals, b)
    tol = 1e-12 if dtype == np.float64 else 1e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)
    assert got.dtype == dtype


@settings(max_examples=40, deadline=None)
@given(
    n_tiles=st.integers(1, 4),
    bt=st.sampled_from([4, 8, 16]),
    w=st.integers(1, 9),
    d=st.integers(1, 17),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_reference_hypothesis(n_tiles, bt, w, d, seed):
    """Property: for every (grid, width, d), kernel == oracle."""
    n = n_tiles * bt
    rng = np.random.default_rng(seed)
    cols, vals = make_ell(rng, n, w, n, np.float64)
    b = jnp.asarray(rng.uniform(-1, 1, size=(n, d)))
    got = ell_spmm(cols, vals, b, block_rows=bt)
    want = ell_spmm_ref(cols, vals, b)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_equivalent_to_dense_matmul(seed):
    """Property: scattering the ELL arrays into a dense A and doing a
    dense matmul gives the same C (padding contributes nothing)."""
    rng = np.random.default_rng(seed)
    n, w, d = 24, 5, 7
    cols, vals = make_ell(rng, n, w, n, np.float64)
    b = jnp.asarray(rng.uniform(-1, 1, size=(n, d)))
    a_dense = np.zeros((n, n))
    for r in range(n):
        for k in range(w):
            a_dense[r, int(cols[r, k])] += float(vals[r, k])
    got = ell_spmm(cols, vals, b, block_rows=n)
    want = dense_spmm_ref(jnp.asarray(a_dense), b)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_rejects_indivisible_grid():
    rng = np.random.default_rng(0)
    cols, vals = make_ell(rng, 10, 2, 10, np.float64)
    b = jnp.zeros((10, 4))
    with pytest.raises(ValueError, match="not divisible"):
        ell_spmm(cols, vals, b, block_rows=3)


def test_grid_tiling_equivalence():
    """Same input through different tilings -> identical output."""
    rng = np.random.default_rng(7)
    n, w, d = 64, 6, 8
    cols, vals = make_ell(rng, n, w, n, np.float64)
    b = jnp.asarray(rng.uniform(-1, 1, size=(n, d)))
    outs = [
        np.asarray(ell_spmm(cols, vals, b, block_rows=bt)) for bt in (8, 16, 32, 64)
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


def test_all_padding_gives_zero():
    n, w, d = 16, 4, 4
    cols = jnp.zeros((n, w), jnp.int32)
    vals = jnp.zeros((n, w), jnp.float64)
    b = jnp.ones((n, d), jnp.float64)
    out = ell_spmm(cols, vals, b, block_rows=n)
    assert np.all(np.asarray(out) == 0.0)


def test_vmem_footprint_within_budget():
    """The auto-chosen tiling must fit a 16 MiB per-core VMEM budget at
    every artifact shape (DESIGN.md §7)."""
    for (n, w, d) in [(16384, 16, 1), (16384, 16, 64), (4096, 8, 16), (65536, 64, 64)]:
        bt = choose_block_rows(n, w, d)
        assert n % bt == 0
        fp = vmem_footprint_bytes(bt, w, d, n)
        assert fp <= 16 << 20, f"(n={n},w={w},d={d}): footprint {fp} exceeds budget"


def test_choose_block_rows_prefers_whole_matrix_when_it_fits():
    assert choose_block_rows(4096, 8, 16) == 4096
    # huge d forces tiling
    assert choose_block_rows(1 << 20, 64, 64) < (1 << 20)
