"""AOT path: lowering produces loadable HLO text and a well-formed
manifest.

The Rust integration test (rust/tests/integration_runtime.rs) closes
the loop by loading these artifacts through PJRT and checking numerics;
here we check the text artifacts themselves.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels.ref import ell_spmm_ref

jax.config.update("jax_enable_x64", True)


def test_hlo_text_structure():
    name, meta, lowered = aot.spmm_variant(256, 4, 8)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "HloModule" in text
    # tuple-returning entry (rust unwraps with to_tuple1)
    assert "tuple" in text.lower()
    assert meta["kind"] == "ell_spmm"
    assert name == "ell_spmm_n256_w4_d8"


def test_gcn_variant_structure():
    name, meta, lowered = aot.gcn_variant(256, 4, 8, 8)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert meta["dout"] == 8
    assert "maximum" in text  # the relu survived lowering


def test_variant_sets():
    small = aot.variant_set("small")
    full = aot.variant_set("full")
    assert len(small) == 2
    assert len(full) == len(small) + 5
    names = [v[0] for v in full]
    assert len(set(names)) == len(names), "duplicate artifact names"
    for d in (1, 4, 16, 64):
        assert f"ell_spmm_n16384_w16_d{d}" in names
    assert "bell_spmm_n4096_mb8_bs8_d16" in names


def test_cli_writes_artifacts_and_manifest(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--variants", "small"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = (out / "manifest.toml").read_text()
    assert "[ell_spmm_n4096_w8_d16]" in manifest
    assert 'kind = "ell_spmm"' in manifest
    assert (out / "ell_spmm_n4096_w8_d16.hlo.txt").exists()
    assert (out / "gcn_n4096_w8_d16_o16.hlo.txt").exists()


def test_bell_variant_structure():
    name, meta, lowered = aot.bell_variant(64, 4, 8, 8)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert meta["bs"] == 8 and meta["kind"] == "bell_spmm"
    assert name == "bell_spmm_n512_mb4_bs8_d8"
    assert "dot" in text  # the per-tile matmul survived lowering


def test_lowered_numerics_via_jax_executable():
    """Compile the lowered module with jax itself and compare numbers —
    catches lowering bugs without needing the rust side."""
    rng = np.random.default_rng(11)
    n, w, d = 64, 3, 5
    cols = jnp.asarray(rng.integers(0, n, size=(n, w)).astype(np.int32))
    vals = jnp.asarray(rng.uniform(-1, 1, size=(n, w)))
    b = jnp.asarray(rng.uniform(-1, 1, size=(n, d)))
    lowered = jax.jit(model.spmm_entry).lower(cols, vals, b)
    compiled = lowered.compile()
    (got,) = compiled(cols, vals, b)
    np.testing.assert_allclose(got, ell_spmm_ref(cols, vals, b), rtol=1e-12, atol=1e-12)
