"""Layer-2 correctness: the JAX model graphs vs references."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import ell_spmm_ref, gcn_layer_ref

jax.config.update("jax_enable_x64", True)


def _rand_problem(rng, n, w, d):
    cols = jnp.asarray(rng.integers(0, n, size=(n, w)).astype(np.int32))
    vals = jnp.asarray(rng.uniform(-1, 1, size=(n, w)))
    b = jnp.asarray(rng.uniform(-1, 1, size=(n, d)))
    return cols, vals, b


def test_spmm_matches_ref():
    rng = np.random.default_rng(1)
    cols, vals, b = _rand_problem(rng, 32, 4, 8)
    np.testing.assert_allclose(
        model.spmm(cols, vals, b, block_rows=16),
        ell_spmm_ref(cols, vals, b),
        rtol=1e-12,
        atol=1e-12,
    )


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), dout=st.integers(1, 9))
def test_gcn_layer_matches_ref(seed, dout):
    rng = np.random.default_rng(seed)
    n, w, d = 32, 3, 6
    cols, vals, b = _rand_problem(rng, n, w, d)
    wgt = jnp.asarray(rng.uniform(-1, 1, size=(d, dout)))
    got = model.gcn_layer(cols, vals, b, wgt)
    want = gcn_layer_ref(cols, vals, b, wgt)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)
    assert bool(jnp.all(got >= 0.0))  # relu

def test_entries_return_tuples():
    rng = np.random.default_rng(3)
    cols, vals, b = _rand_problem(rng, 16, 2, 4)
    out = model.spmm_entry(cols, vals, b)
    assert isinstance(out, tuple) and len(out) == 1
    wgt = jnp.asarray(rng.uniform(-1, 1, size=(4, 4)))
    out = model.gcn_entry(cols, vals, b, wgt)
    assert isinstance(out, tuple) and len(out) == 1


def test_spmm_is_f64_end_to_end():
    rng = np.random.default_rng(4)
    cols, vals, b = _rand_problem(rng, 16, 2, 4)
    assert model.spmm(cols, vals, b, block_rows=16).dtype == jnp.float64
